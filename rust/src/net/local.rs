//! In-process cluster transport: one mailbox (mpsc channel) per peer.
//!
//! Honest peers use `broadcast` (same bytes to everyone). Byzantine peers
//! may use `broadcast_split` to send contradicting payloads; the
//! transport then mimics GossipSub relay by delivering *every* variant to
//! *every* peer, so honest receivers observe the equivocation and ban the
//! sender (the paper's eventual-consistency assumption, footnote 4).
//!
//! Receives run in one of two modes (`RecvMode`):
//!
//! - `Blocking` — the classic one-OS-thread-per-peer execution model:
//!   `recv_match` parks on the channel until a matching envelope arrives
//!   or the timeout elapses (timeout ⇒ protocol violation upstream).
//! - `Drain` — used by the pooled peer scheduler, which guarantees (via a
//!   cluster-wide barrier between protocol stages) that every message a
//!   stage may wait for has already been sent. `recv_match` drains the
//!   channel into the pending buffer, orders it by the canonical
//!   `(step, slot, from)` key — stable, so a Byzantine sender's
//!   equivocation variants keep their per-sender FIFO order — and either
//!   returns a match or reports `Timeout` immediately. The deterministic
//!   order makes a pooled run bit-identical to a threaded run of the
//!   same seed regardless of worker interleaving.

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::Duration;

use super::{Envelope, MsgClass, PeerId, TrafficStats};
use crate::crypto::{Mont, PublicKey, SecretKey};

/// Shared, immutable cluster facts.
pub struct ClusterInfo {
    pub n_peers: usize,
    pub public_keys: Vec<PublicKey>,
    pub stats: TrafficStats,
    /// Whether receivers verify envelope signatures (configurable: long
    /// training benches can disable to isolate protocol numerics cost).
    pub verify_signatures: bool,
}

/// How `recv_match` waits for messages (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum RecvMode {
    /// Block on the channel up to `timeout` (per-peer-thread execution).
    #[default]
    Blocking,
    /// Never block: drain the channel, order deterministically, and treat
    /// a missing message as an immediate timeout (pooled scheduler; a
    /// stage barrier guarantees expected messages were already sent).
    Drain,
}

/// A peer's endpoint: its mailbox plus senders to every other peer.
pub struct PeerNet {
    pub id: PeerId,
    pub info: Arc<ClusterInfo>,
    pub secret: SecretKey,
    pub mont: Mont,
    senders: Vec<Sender<Envelope>>,
    mailbox: Receiver<Envelope>,
    /// Buffered envelopes that arrived ahead of the phase we're waiting on.
    pending: Vec<Envelope>,
    /// Default receive timeout: elapsed ⇒ counterpart considered in
    /// violation of the protocol (triggers ELIMINATE upstream).
    pub timeout: Duration,
    pub recv_mode: RecvMode,
}

/// Build a fully connected in-process cluster.
pub fn build_cluster(
    n: usize,
    key_seed: u64,
    gossip_fanout: u64,
    verify_signatures: bool,
) -> Vec<PeerNet> {
    let mont = Mont::new();
    let secrets: Vec<SecretKey> = (0..n).map(|i| crate::crypto::keygen(&mont, key_seed + i as u64)).collect();
    let public_keys: Vec<PublicKey> = secrets.iter().map(|s| s.public).collect();
    let info = Arc::new(ClusterInfo {
        n_peers: n,
        public_keys,
        stats: TrafficStats::new(n, gossip_fanout),
        verify_signatures,
    });
    let mut senders = Vec::with_capacity(n);
    let mut receivers = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = channel();
        senders.push(tx);
        receivers.push(rx);
    }
    receivers
        .into_iter()
        .zip(secrets)
        .enumerate()
        .map(|(id, (mailbox, secret))| PeerNet {
            id,
            info: info.clone(),
            secret,
            mont: mont.clone(),
            senders: senders.clone(),
            mailbox,
            pending: Vec::new(),
            timeout: Duration::from_secs(30),
            recv_mode: RecvMode::Blocking,
        })
        .collect()
}

#[derive(Debug)]
pub enum RecvError {
    /// No matching message within the timeout.
    Timeout,
    /// All senders disconnected (cluster shut down).
    Disconnected,
}

impl PeerNet {
    fn make_envelope(
        &self,
        step: u64,
        slot: u32,
        class: MsgClass,
        payload: Vec<u8>,
        broadcast: bool,
    ) -> Envelope {
        let mut env = Envelope {
            from: self.id,
            step,
            slot,
            class,
            payload: payload.into(),
            broadcast,
            signature: None,
        };
        // When the cluster runs with verification off (numerics benches),
        // signing would be pure waste: nobody ever checks the bytes.
        if self.info.verify_signatures {
            env.sign_with(&self.mont, &self.secret);
        }
        env
    }

    /// Point-to-point send.
    pub fn send(&self, to: PeerId, step: u64, slot: u32, class: MsgClass, payload: Vec<u8>) {
        let bytes = payload.len();
        let env = self.make_envelope(step, slot, class, payload, false);
        self.info.stats.record_p2p(self.id, class, bytes);
        // Ignore send errors: the receiver may have been banned/stopped.
        let _ = self.senders[to].send(env);
    }

    /// Broadcast the same payload to all peers (including self, so the
    /// sender's own bookkeeping sees the message exactly like others do).
    pub fn broadcast(&self, step: u64, slot: u32, class: MsgClass, payload: Vec<u8>) {
        let bytes = payload.len();
        let env = self.make_envelope(step, slot, class, payload, true);
        self.info.stats.record_broadcast(self.id, class, bytes);
        for tx in &self.senders {
            let _ = tx.send(env.clone());
        }
    }

    /// Byzantine equivocation: send per-recipient payload variants. The
    /// relay layer eventually delivers every distinct variant to every
    /// peer; we model that by delivering all variants to everyone.
    pub fn broadcast_split(
        &self,
        step: u64,
        slot: u32,
        class: MsgClass,
        variants: Vec<(PeerId, Vec<u8>)>,
    ) {
        let mut distinct: Vec<Vec<u8>> = Vec::new();
        for (_, p) in &variants {
            if !distinct.contains(p) {
                distinct.push(p.clone());
            }
        }
        for payload in distinct {
            let bytes = payload.len();
            let env = self.make_envelope(step, slot, class, payload, true);
            self.info.stats.record_broadcast(self.id, class, bytes);
            for tx in &self.senders {
                let _ = tx.send(env.clone());
            }
        }
    }

    /// Drain every immediately available envelope into `pending` (dropping
    /// forged ones) and sort it by the canonical delivery key. The sort is
    /// stable, so multiple envelopes with the same key — equivocation
    /// variants from one sender — stay in their per-sender FIFO order,
    /// exactly as a blocking receiver would have observed them.
    fn refill_pending_ordered(&mut self) {
        let mut added = false;
        while let Ok(env) = self.mailbox.try_recv() {
            if self.info.verify_signatures
                && !env.verify_with(&self.mont, &self.info.public_keys[env.from])
            {
                continue; // forged — drop silently
            }
            self.pending.push(env);
            added = true;
        }
        if added {
            // Stable + adaptive: appending to an already-sorted prefix
            // keeps re-sorting near-linear, so per-collect refills stay
            // cheap even at hundreds of peers.
            self.pending.sort_by_key(|e| (e.step, e.slot, e.from));
        }
    }

    /// Receive the next envelope matching `pred`, buffering mismatches.
    /// Envelopes with invalid signatures are dropped (per the paper: a
    /// receiver ignores unsigned/forged messages).
    pub fn recv_match<F: Fn(&Envelope) -> bool>(&mut self, pred: F) -> Result<Envelope, RecvError> {
        if self.recv_mode == RecvMode::Drain {
            self.refill_pending_ordered();
            return match self.pending.iter().position(|e| pred(e)) {
                // `remove`, not `swap_remove`: keep the canonical order.
                Some(pos) => Ok(self.pending.remove(pos)),
                None => Err(RecvError::Timeout),
            };
        }
        if let Some(pos) = self.pending.iter().position(|e| pred(e)) {
            return Ok(self.pending.swap_remove(pos));
        }
        let deadline = std::time::Instant::now() + self.timeout;
        loop {
            let remaining = deadline.saturating_duration_since(std::time::Instant::now());
            if remaining.is_zero() {
                return Err(RecvError::Timeout);
            }
            match self.mailbox.recv_timeout(remaining) {
                Ok(env) => {
                    if self.info.verify_signatures
                        && !env.verify_with(&self.mont, &self.info.public_keys[env.from])
                    {
                        continue; // forged — drop silently
                    }
                    if pred(&env) {
                        return Ok(env);
                    }
                    self.pending.push(env);
                }
                Err(RecvTimeoutError::Timeout) => return Err(RecvError::Timeout),
                Err(RecvTimeoutError::Disconnected) => return Err(RecvError::Disconnected),
            }
        }
    }

    /// Drain any already-buffered or immediately available envelopes
    /// matching `pred` without blocking.
    pub fn drain_match<F: Fn(&Envelope) -> bool>(&mut self, pred: F) -> Vec<Envelope> {
        if self.recv_mode == RecvMode::Drain {
            // Pull everything into `pending` first so the result comes out
            // in canonical order (the loop below then finds the channel
            // empty and just partitions the buffer).
            self.refill_pending_ordered();
        }
        let mut out = Vec::new();
        let mut keep = Vec::new();
        for e in self.pending.drain(..) {
            if pred(&e) {
                out.push(e);
            } else {
                keep.push(e);
            }
        }
        self.pending = keep;
        while let Ok(env) = self.mailbox.try_recv() {
            if self.info.verify_signatures
                && !env.verify_with(&self.mont, &self.info.public_keys[env.from])
            {
                continue;
            }
            if pred(&env) {
                out.push(env);
            } else {
                self.pending.push(env);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::slots;

    #[test]
    fn p2p_roundtrip() {
        let mut cluster = build_cluster(2, 100, 8, true);
        let p1 = cluster.pop().unwrap();
        let mut p0 = cluster.pop().unwrap();
        p1.send(0, 1, slots::GRAD_PART, MsgClass::GradientPart, vec![42]);
        let env = p0
            .recv_match(|e| e.from == 1 && e.slot == slots::GRAD_PART)
            .unwrap();
        assert_eq!(env.payload.to_vec(), vec![42]);
        assert_eq!(env.step, 1);
    }

    #[test]
    fn broadcast_reaches_everyone_including_self() {
        let mut cluster = build_cluster(3, 200, 8, true);
        cluster[0].broadcast(0, slots::GRAD_COMMIT, MsgClass::Commitment, vec![7]);
        for p in cluster.iter_mut() {
            let env = p.recv_match(|e| e.slot == slots::GRAD_COMMIT).unwrap();
            assert_eq!(env.from, 0);
            assert_eq!(env.payload.to_vec(), vec![7]);
        }
    }

    #[test]
    fn split_broadcast_delivers_all_variants() {
        let mut cluster = build_cluster(3, 300, 8, true);
        cluster[2].broadcast_split(
            0,
            slots::GRAD_COMMIT,
            MsgClass::Commitment,
            vec![(0, vec![1]), (1, vec![2])],
        );
        let mut p0 = cluster.remove(0);
        let a = p0.recv_match(|e| e.from == 2).unwrap();
        let b = p0.recv_match(|e| e.from == 2).unwrap();
        let mut seen: Vec<u8> = vec![a.payload[0], b.payload[0]];
        seen.sort_unstable();
        assert_eq!(seen, vec![1, 2]); // both variants visible → equivocation evidence
    }

    #[test]
    fn pending_buffer_preserves_out_of_order() {
        let mut cluster = build_cluster(2, 400, 8, true);
        let p1 = cluster.pop().unwrap();
        let mut p0 = cluster.pop().unwrap();
        p1.send(0, 5, slots::VERIFY_SCALARS, MsgClass::Verification, vec![9]);
        p1.send(0, 5, slots::GRAD_PART, MsgClass::GradientPart, vec![8]);
        // Ask for the later-sent first; earlier one must stay pending.
        let g = p0.recv_match(|e| e.slot == slots::GRAD_PART).unwrap();
        assert_eq!(g.payload.to_vec(), vec![8]);
        let v = p0.recv_match(|e| e.slot == slots::VERIFY_SCALARS).unwrap();
        assert_eq!(v.payload.to_vec(), vec![9]);
    }

    #[test]
    fn timeout_reported() {
        let mut cluster = build_cluster(2, 500, 8, true);
        cluster[0].timeout = Duration::from_millis(10);
        let err = cluster[0].recv_match(|_| true);
        assert!(matches!(err, Err(RecvError::Timeout)));
    }

    #[test]
    fn drain_mode_orders_deterministically_and_never_blocks() {
        let mut cluster = build_cluster(2, 700, 8, true);
        let p1 = cluster.pop().unwrap();
        let mut p0 = cluster.pop().unwrap();
        p0.recv_mode = RecvMode::Drain;
        // Nothing sent yet: immediate timeout instead of a 30 s park.
        let t0 = std::time::Instant::now();
        assert!(matches!(p0.recv_match(|_| true), Err(RecvError::Timeout)));
        assert!(t0.elapsed() < Duration::from_secs(1));
        // Sent out of canonical order; drained in (step, slot, from) order.
        p1.send(0, 3, slots::GRAD_PART, MsgClass::GradientPart, vec![3]);
        p1.send(0, 1, slots::GRAD_PART, MsgClass::GradientPart, vec![1]);
        let a = p0.recv_match(|e| e.slot == slots::GRAD_PART).unwrap();
        let b = p0.recv_match(|e| e.slot == slots::GRAD_PART).unwrap();
        assert_eq!((a.step, b.step), (1, 3));
    }

    #[test]
    fn signatures_skipped_when_verification_disabled() {
        let mut cluster = build_cluster(2, 800, 8, false);
        let p1 = cluster.pop().unwrap();
        let mut p0 = cluster.pop().unwrap();
        p1.send(0, 0, slots::GRAD_PART, MsgClass::GradientPart, vec![5]);
        let env = p0.recv_match(|e| e.from == 1).unwrap();
        assert!(env.signature.is_none());
        assert_eq!(env.payload.to_vec(), vec![5]);
    }

    #[test]
    fn traffic_recorded() {
        let cluster = build_cluster(2, 600, 4, true);
        cluster[0].send(1, 0, slots::GRAD_PART, MsgClass::GradientPart, vec![0; 100]);
        cluster[0].broadcast(0, slots::GRAD_COMMIT, MsgClass::Commitment, vec![0; 32]);
        let info = cluster[0].info.clone();
        assert_eq!(info.stats.total_bytes(0), 100 + 32 * 4);
    }
}
