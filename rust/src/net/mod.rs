//! Peer-to-peer message substrate for the simulated cluster.
//!
//! Every peer owns a mailbox; the transport (`local`) delivers signed
//! envelopes between peers whether they run on their own OS threads
//! (blocking receives) or are multiplexed over a worker pool
//! (deterministic drain-mode receives). Broadcast uses a
//! logical broadcast channel with GossipSub-style cost accounting
//! (`stats`) and equivocation detection (`gossip`): a peer that signs two
//! contradicting messages for the same protocol slot is banned by every
//! honest receiver, matching footnote 4 of the paper.

pub mod gossip;
pub mod local;
pub mod stats;

use crate::crypto::{sign, verify, Mont, PublicKey, SecretKey, Signature};
use std::sync::Arc;
pub use stats::{MsgClass, TrafficStats};

/// Peer identifier: index into the initial roster (stable across bans).
pub type PeerId = usize;

/// A transported message.
#[derive(Clone, Debug)]
pub struct Envelope {
    pub from: PeerId,
    /// Training step this message belongs to.
    pub step: u64,
    /// Protocol slot within the step (phase tag + sub-index); together
    /// with `step` this is the equivocation key for broadcasts.
    pub slot: u32,
    pub class: MsgClass,
    /// Payload bytes, reference-counted so a broadcast to N receivers
    /// clones a pointer, not the buffer. Commit vectors are O(n) hashes,
    /// so per-receiver copies would cost O(n³) bytes cluster-wide — the
    /// difference between a 512-peer sweep fitting in memory or not.
    pub payload: Arc<[u8]>,
    /// True if this envelope was sent on the broadcast channel.
    pub broadcast: bool,
    pub signature: Option<Signature>,
}

impl Envelope {
    /// The byte string covered by the signature (everything that
    /// identifies the message and its content).
    pub fn signing_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.payload.len() + 32);
        out.extend_from_slice(&(self.from as u64).to_le_bytes());
        out.extend_from_slice(&self.step.to_le_bytes());
        out.extend_from_slice(&self.slot.to_le_bytes());
        out.push(self.class as u8);
        out.push(self.broadcast as u8);
        out.extend_from_slice(&self.payload);
        out
    }

    pub fn sign_with(&mut self, mont: &Mont, sk: &SecretKey) {
        self.signature = Some(sign(mont, sk, &self.signing_bytes()));
    }

    pub fn verify_with(&self, mont: &Mont, pk: &PublicKey) -> bool {
        match &self.signature {
            Some(sig) => verify(mont, pk, &self.signing_bytes(), sig),
            None => false,
        }
    }
}

/// Protocol slot tags (high byte of `slot`); low bytes index sub-slots
/// (e.g. which partition a commitment refers to).
pub mod slots {
    pub const GRAD_COMMIT: u32 = 0x0100_0000;
    pub const GRAD_PART: u32 = 0x0200_0000;
    pub const AGG_COMMIT: u32 = 0x0300_0000;
    pub const AGG_PART: u32 = 0x0400_0000;
    pub const MPRNG_COMMIT: u32 = 0x0500_0000;
    pub const MPRNG_REVEAL: u32 = 0x0600_0000;
    pub const VERIFY_SCALARS: u32 = 0x0700_0000;
    pub const CHECK_VOTE: u32 = 0x0800_0000;
    pub const ACCUSE: u32 = 0x0900_0000;
    pub const ELIMINATE: u32 = 0x0A00_0000;
    pub const VALIDATION_OK: u32 = 0x0B00_0000;
    pub const JOIN: u32 = 0x0C00_0000;
    pub const VERIFY_DONE: u32 = 0x0D00_0000;

    /// Compose a slot from a tag and a sub-index (< 2^24).
    pub fn sub(tag: u32, idx: usize) -> u32 {
        debug_assert!(idx < (1 << 24));
        tag | idx as u32
    }

    pub fn tag(slot: u32) -> u32 {
        slot & 0xFF00_0000
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::keygen;

    #[test]
    fn envelope_sign_verify() {
        let mont = Mont::new();
        let sk = keygen(&mont, 9);
        let mut env = Envelope {
            from: 3,
            step: 17,
            slot: slots::sub(slots::GRAD_COMMIT, 5),
            class: MsgClass::Commitment,
            payload: vec![1, 2, 3].into(),
            broadcast: true,
            signature: None,
        };
        assert!(!env.verify_with(&mont, &sk.public));
        env.sign_with(&mont, &sk);
        assert!(env.verify_with(&mont, &sk.public));
        // Any field change invalidates.
        let mut e2 = env.clone();
        e2.step = 18;
        assert!(!e2.verify_with(&mont, &sk.public));
        let mut e3 = env.clone();
        e3.payload = vec![99, 2, 3].into();
        assert!(!e3.verify_with(&mont, &sk.public));
    }

    #[test]
    fn slot_composition() {
        let s = slots::sub(slots::ACCUSE, 0x1234);
        assert_eq!(slots::tag(s), slots::ACCUSE);
        assert_eq!(s & 0x00FF_FFFF, 0x1234);
    }
}
