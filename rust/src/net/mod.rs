//! Peer-to-peer message substrate for the simulated cluster.
//!
//! The protocol layer talks to the network exclusively through the
//! [`Transport`] trait — the seam every backend plugs into:
//!
//! - [`local::PeerNet`] — the perfect in-process fabric: one mailbox
//!   (mpsc channel) per peer, zero latency, zero loss. The default.
//! - [`sim::SimNet`] — wraps the local fabric with a deterministic,
//!   seeded per-link network-condition model ([`sim::NetworkProfile`]):
//!   transmission loss with retransmits, tail-latency delays measured in
//!   protocol phases, straggler uplinks, and peer-scoped blackout
//!   windows — all reproducible bit-for-bit for a given seed.
//! - [`socket::SocketNet`] — the first backend that leaves the process:
//!   a loopback/LAN TCP mesh with a length-prefixed signed-envelope
//!   frame codec and a JSON-roster handshake. Per-link reader threads
//!   feed the same mailbox/pending machinery (`local::Inbox`) the
//!   in-process fabric uses, so delivery semantics — and the metrics of
//!   a perfect-link run — are bit-identical across the wire
//!   (`harness::cluster` proves it by digest).
//!
//! Every backend delivers signed envelopes whether peers run on their
//! own OS threads (blocking receives) or are multiplexed over a worker
//! pool (deterministic drain-mode receives). Broadcast uses a logical
//! broadcast channel with GossipSub-style cost accounting (`stats`) and
//! equivocation detection (`gossip`): a peer that signs two
//! contradicting messages for the same protocol slot is banned by every
//! honest receiver, matching footnote 4 of the paper.

pub mod auth;
pub mod gossip;
pub mod local;
pub mod sim;
pub mod socket;
pub mod stats;

use crate::crypto::{sign, verify, Mont, PublicKey, SecretKey, Signature};
use std::sync::Arc;
use std::time::Duration;

pub use auth::{requires_signature, MessageAuth, NoAuth, SchnorrAuth, SessionAuth};
pub use local::{build_cluster, ClusterInfo, PeerNet, RecvError, RecvMode};
pub use sim::{build_transports, FaultStats, NetworkProfile, PeerFaults, SimNet};
pub use socket::{
    bind_ephemeral, derive_keypair, Roster, RosterEntry, SocketConfig, SocketNet,
};
pub use stats::{MsgClass, TrafficStats};

/// Peer identifier: index into the initial roster (stable across bans).
pub type PeerId = usize;

/// A transported message.
#[derive(Clone, Debug)]
pub struct Envelope {
    pub from: PeerId,
    /// Training step this message belongs to.
    pub step: u64,
    /// Protocol slot within the step (phase tag + sub-index); together
    /// with `step` this is the equivocation key for broadcasts.
    pub slot: u32,
    pub class: MsgClass,
    /// Payload bytes, reference-counted so a broadcast to N receivers
    /// clones a pointer, not the buffer. Commit vectors are O(n) hashes,
    /// so per-receiver copies would cost O(n³) bytes cluster-wide — the
    /// difference between a 512-peer sweep fitting in memory or not.
    pub payload: Arc<[u8]>,
    /// True if this envelope was sent on the broadcast channel.
    pub broadcast: bool,
    /// Transport-layer delivery gate: the receiver's logical phase clock
    /// must reach this value before the envelope becomes visible
    /// (0 = immediate). Routing metadata, not message content — it is
    /// stamped by the network model, so it is *not* covered by the
    /// signature, exactly like a relay timestamp would not be.
    pub deliver_at: u64,
    pub signature: Option<Signature>,
}

impl Envelope {
    /// The byte string covered by the signature (everything that
    /// identifies the message and its content).
    pub fn signing_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.payload.len() + 32);
        out.extend_from_slice(&(self.from as u64).to_le_bytes());
        out.extend_from_slice(&self.step.to_le_bytes());
        out.extend_from_slice(&self.slot.to_le_bytes());
        out.push(self.class as u8);
        out.push(self.broadcast as u8);
        out.extend_from_slice(&self.payload);
        out
    }

    pub fn sign_with(&mut self, mont: &Mont, sk: &SecretKey) {
        self.signature = Some(sign(mont, sk, &self.signing_bytes()));
    }

    pub fn verify_with(&self, mont: &Mont, pk: &PublicKey) -> bool {
        match &self.signature {
            Some(sig) => verify(mont, pk, &self.signing_bytes(), sig),
            None => false,
        }
    }
}

/// Protocol slot tags (high byte of `slot`); low bytes index sub-slots
/// (e.g. which partition a commitment refers to).
pub mod slots {
    pub const GRAD_COMMIT: u32 = 0x0100_0000;
    pub const GRAD_PART: u32 = 0x0200_0000;
    pub const AGG_COMMIT: u32 = 0x0300_0000;
    pub const AGG_PART: u32 = 0x0400_0000;
    pub const MPRNG_COMMIT: u32 = 0x0500_0000;
    pub const MPRNG_REVEAL: u32 = 0x0600_0000;
    pub const VERIFY_SCALARS: u32 = 0x0700_0000;
    pub const CHECK_VOTE: u32 = 0x0800_0000;
    pub const ACCUSE: u32 = 0x0900_0000;
    pub const ELIMINATE: u32 = 0x0A00_0000;
    pub const VALIDATION_OK: u32 = 0x0B00_0000;
    pub const JOIN: u32 = 0x0C00_0000;
    pub const VERIFY_DONE: u32 = 0x0D00_0000;
    pub const LEAVE: u32 = 0x0E00_0000;
    /// Consensus admission (`coordinator::consensus`): a candidate's
    /// signed petition to join, broadcast before it holds any roster
    /// slot. Sub-index = candidate id.
    pub const JOIN_REQUEST: u32 = 0x0F00_0000;
    /// Rank-R message of the roster agreement round: an incumbent's
    /// proposed roster document for the next epoch.
    pub const ROSTER_PROPOSE: u32 = 0x1000_0000;
    /// Rank-A message: an incumbent's vote (document digest).
    pub const ROSTER_VOTE: u32 = 0x1100_0000;
    /// Rank-B message: a commit certificate quoting ≥ 2f+1 votes.
    pub const ROSTER_CERT: u32 = 0x1200_0000;

    /// Compose a slot from a tag and a sub-index (< 2^24).
    pub fn sub(tag: u32, idx: usize) -> u32 {
        debug_assert!(idx < (1 << 24));
        tag | idx as u32
    }

    pub fn tag(slot: u32) -> u32 {
        slot & 0xFF00_0000
    }
}

/// The pluggable transport seam: everything the staged BTARD protocol
/// needs from a network backend. `coordinator::step` and both training
/// loops are written against this trait only, so a backend swap (perfect
/// local fabric, seeded fault simulation, real sockets, multi-process)
/// never touches protocol code.
///
/// Contract, shared by every backend:
///
/// - **Canonical drain order.** In `RecvMode::Drain`, deliverable
///   envelopes are observed in `(step, slot, from)` order (stable for
///   equal keys), which is what makes pooled runs bit-identical across
///   worker counts.
/// - **Logical phase clock.** `tick()` is called once at the start of
///   every protocol stage. Backends that model latency use it as the
///   delivery clock: an envelope stamped `deliver_at = c` is invisible
///   to receives until the *receiver's* clock reaches `c`. The perfect
///   fabric stamps every envelope 0, so its clock is inert.
/// - **Self loopback is exempt from faults.** A peer always sees its own
///   broadcasts immediately: loopback never crosses the network.
pub trait Transport: Send {
    /// This endpoint's peer id (stable index into the initial roster).
    fn id(&self) -> PeerId;
    /// Shared immutable cluster facts (roster size, keys, traffic stats).
    fn info(&self) -> &Arc<ClusterInfo>;
    /// Set the blocking-receive timeout (no-op for drain-mode receives).
    fn set_timeout(&mut self, timeout: Duration);
    fn set_recv_mode(&mut self, mode: RecvMode);
    /// Advance the logical phase clock (called at every stage entry).
    fn tick(&mut self);
    /// Current logical phase-clock value. A mid-run joiner fast-forwards
    /// its clock to the sponsor's snapshot value so latency-gated
    /// deliveries (network simulation) reference a cluster-consistent
    /// clock instead of the joiner's held-out one.
    fn clock(&self) -> u64;
    /// Install a pre-membership horizon: drop every buffered envelope —
    /// including latency-parked ones — from steps before `step`, and
    /// gate future arrivals the same way. A mid-run joiner calls this at
    /// snapshot install so the in-process fabrics match the wire, which
    /// never carries pre-join traffic.
    fn set_min_step(&mut self, step: u64);
    /// Point-to-point send.
    fn send(&mut self, to: PeerId, step: u64, slot: u32, class: MsgClass, payload: Vec<u8>);
    /// Broadcast the same payload to all peers (including self).
    fn broadcast(&mut self, step: u64, slot: u32, class: MsgClass, payload: Vec<u8>);
    /// Byzantine equivocation: per-recipient payload variants, each
    /// eventually relayed to every peer.
    fn broadcast_split(
        &mut self,
        step: u64,
        slot: u32,
        class: MsgClass,
        variants: Vec<(PeerId, Vec<u8>)>,
    );
    /// Receive the next envelope for exactly `(step, slot)` that also
    /// satisfies `pred`, buffering mismatches. Keyed receives are the
    /// protocol's hot path: drain-mode backends locate the `(step, slot)`
    /// range by binary search over the sorted pending buffer.
    fn recv_keyed(
        &mut self,
        step: u64,
        slot: u32,
        pred: &dyn Fn(&Envelope) -> bool,
    ) -> Result<Envelope, RecvError>;
    /// Drain every already-deliverable envelope matching `pred` without
    /// blocking (end-of-step control-traffic sweep).
    fn drain_match(&mut self, pred: &dyn Fn(&Envelope) -> bool) -> Vec<Envelope>;
    /// Per-peer network-fault counters, when the backend injects faults.
    fn fault_handle(&self) -> Option<Arc<FaultStats>> {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::keygen;

    #[test]
    fn envelope_sign_verify() {
        let mont = Mont::new();
        let sk = keygen(&mont, 9);
        let mut env = Envelope {
            from: 3,
            step: 17,
            slot: slots::sub(slots::GRAD_COMMIT, 5),
            class: MsgClass::Commitment,
            payload: vec![1, 2, 3].into(),
            broadcast: true,
            deliver_at: 0,
            signature: None,
        };
        assert!(!env.verify_with(&mont, &sk.public));
        env.sign_with(&mont, &sk);
        assert!(env.verify_with(&mont, &sk.public));
        // Any field change invalidates.
        let mut e2 = env.clone();
        e2.step = 18;
        assert!(!e2.verify_with(&mont, &sk.public));
        let mut e3 = env.clone();
        e3.payload = vec![99, 2, 3].into();
        assert!(!e3.verify_with(&mont, &sk.public));
        // Transport routing metadata is NOT covered: the network model
        // re-stamps it without invalidating the sender's signature.
        let mut e4 = env.clone();
        e4.deliver_at = 99;
        assert!(e4.verify_with(&mont, &sk.public));
    }

    #[test]
    fn slot_composition() {
        let s = slots::sub(slots::ACCUSE, 0x1234);
        assert_eq!(slots::tag(s), slots::ACCUSE);
        assert_eq!(s & 0x00FF_FFFF, 0x1234);
    }
}
