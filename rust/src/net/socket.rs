//! Real-socket transport: the first `Transport` backend that leaves the
//! process.
//!
//! `SocketNet` implements the full transport contract over a loopback/LAN
//! TCP mesh so BTARD runs between *actual* OS processes — the setting the
//! paper (and DeDLOC-style open collaborations) assumes, where peers
//! share nothing but a roster and the wire. The pieces:
//!
//! - **Frame codec.** Length-prefixed signed-envelope frames
//!   (`encode_envelope` / `FrameReader`): a fixed `BTRD` magic, a u32
//!   body length, and a body carrying either a roster handshake HELLO or
//!   a protocol [`Envelope`]. The reader rejects oversized frames before
//!   allocating and treats any malformed byte (bad magic, unknown kind,
//!   bad class, truncated body) as a connection-fatal error — a hostile
//!   peer can kill its own link, never the receiver. `deliver_at` is
//!   transport routing metadata and is *not* serialized: a socket link is
//!   a perfect link, every received envelope is stamped 0.
//! - **Roster handshake.** Peers find each other through a JSON
//!   [`Roster`] (peer id, listen address, hex public key). Links are
//!   **unidirectional**: for every ordered pair (i → j) the *sender*
//!   dials the receiver's listener and opens a connection that only
//!   ever carries i's envelopes, prefixed by a HELLO frame (id, pubkey)
//!   the acceptor checks against the roster. One connection per
//!   direction is a deliberate correctness choice, not an accident: a
//!   peer that exits early (banned mid-run) closes sockets that may
//!   carry unread inbound data, and TCP answers further traffic on such
//!   a socket with RST — which on the *other* end discards any
//!   undelivered receive data on that same connection. With
//!   bidirectional links that could silently eat an honest peer's
//!   still-buffered envelopes; with send-only links every RST lands on
//!   a socket the victim never reads from, so nothing can be lost.
//!   When signature verification is on, the HELLO itself is signed with
//!   the sender's roster key (so an impostor cannot claim another
//!   peer's link), and the event loop additionally drops any
//!   point-to-point frame whose `from` does not match the link's
//!   authenticated peer. With verification off (`--no-sigs`, a
//!   benchmarking mode) nothing on the wire is authenticated — by
//!   construction, not oversight.
//! - **Event-loop engine.** One I/O thread per endpoint owns every
//!   socket: the listener, all inbound links (each with its
//!   [`FrameReader`] as per-link decode state), all outbound links
//!   (non-blocking, buffered, POLLOUT-driven) and every session-MAC
//!   send counter, multiplexed with poll(2). The driver thread signs
//!   envelopes and queues commands; handshakes and lazy dials run on
//!   short-lived bounded helper threads. Threads and fds stay O(1) per
//!   endpoint plus O(open links) — not O(n) threads — which is what
//!   lets a 512-peer loopback cluster fit in an ordinary process
//!   budget.
//! - **Gossip broadcast overlay** (`SocketConfig::gossip`). Broadcasts
//!   ride a deterministic relay graph derived per membership epoch as a
//!   pure function of (roster, seed, fanout) — see
//!   [`super::gossip::Overlay`]. Each endpoint writes a broadcast to
//!   its O(min(fanout, log n)) overlay out-neighbours; receivers relay
//!   the first copy of each distinct (origin, step, slot, digest) once,
//!   never back to the origin, so per-peer broadcast bytes drop from
//!   O(n) to O(fanout·log n). Contradictory variants (equivocation
//!   attempts) are relayed too — capped per key — so ban evidence
//!   reaches every honest peer exactly as the full mesh would have
//!   delivered it. Adjudication-bound slots keep their transferable
//!   Schnorr envelope signatures through relays: the link authenticates
//!   the relayer, the envelope signature authenticates the origin, and
//!   a forged relay dies at the Inbox's signature gate. Point-to-point
//!   slots (gradient parts, snapshots) dial direct links lazily as
//!   before.
//! - **Shared delivery semantics.** The loop decodes frames into the
//!   same mpsc mailbox the in-process fabric uses, behind the same
//!   [`Inbox`]: signature gating, the canonical `(step, slot, from)`
//!   pending order, keyed binary-search collects and the logical phase
//!   clock all survive the wire unchanged. A socket peer therefore runs
//!   the *blocking* receive mode of the threaded execution model (there
//!   is no cross-process stage barrier to make drain mode's never-block
//!   contract sound), and the threaded path is bit-identical to the
//!   pooled one — which is how a multi-process cluster (full-mesh *or*
//!   gossip) reproduces the in-process golden digest bit-for-bit
//!   (`harness::cluster`, `rust/tests/socket_transport.rs`).
//!
//! Simulation-grade caveats, deliberate and documented: per-peer keys are
//! derived deterministically from the run seed ([`derive_keypair`], the
//! same derivation the in-process builder uses — that is what makes the
//! signatures, and so the digests, comparable), and the signed HELLO is
//! replayable (a man-in-the-middle that captured one can occupy the
//! victim's inbound slot — a denial of service, never a forgery: every
//! envelope signature still fails against the roster key).

use std::io::{ErrorKind, Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream, ToSocketAddrs};
use std::os::unix::io::AsRawFd;
use std::os::unix::net::UnixStream;
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use super::auth::{MessageAuth, NoAuth, SchnorrAuth, SessionAuth};
use super::gossip::{OverlaySchedule, RelayTracker, Seen};
use super::local::{distinct_variants, ClusterInfo, Inbox};
use super::{Envelope, MsgClass, PeerId, RecvError, RecvMode, TrafficStats, Transport};
use crate::crypto::{
    hmac_sha256, hmac_sha256_batch, keygen, sha256, shared_secret, sign, verify, Mont, PublicKey,
    SecretKey, Signature,
};
use crate::util::json::Json;
use crate::util::{hex, unhex};

/// Frame magic: every frame starts with these four bytes.
pub const MAGIC: [u8; 4] = *b"BTRD";
/// Default cap on a frame body (64 MiB ≈ a 16M-parameter f32 gradient
/// part) — a hostile length prefix must not become an allocation bomb.
pub const DEFAULT_MAX_FRAME: usize = 64 << 20;
/// Default cap on one outbound link's unflushed backlog. A slow or dead
/// peer must cost its own link, never its neighbours' memory: once this
/// many bytes sit unflushed the link is condemned (see
/// `IoLoop::enforce_backlog`). One max-size frame still fits.
pub const DEFAULT_MAX_LINK_BACKLOG: usize = 64 << 20;

const KIND_HELLO: u8 = 1;
const KIND_ENVELOPE: u8 = 2;
/// A session-MAC envelope frame: `kind ‖ seq ‖ mac ‖ envelope fields`.
/// Only valid on a link whose handshake negotiated session-MAC mode.
const KIND_MAC_ENVELOPE: u8 = 3;
/// kind + from + step + slot + class + broadcast + sig flag.
const ENVELOPE_FIXED: usize = 1 + 8 + 8 + 4 + 1 + 1 + 1;
/// kind + seq + 32-byte HMAC, ahead of the ordinary envelope fields.
const MAC_FIXED: usize = 1 + 8 + 32;
/// kind + id + epoch + nonce + pubkey + mac flag + sig flag (+ 64-byte
/// signature when flagged).
const HELLO_FIXED: usize = 1 + 8 + 8 + 32 + 32 + 1 + 1;

/// Why a frame (and with it, the connection) was rejected.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FrameError {
    /// Stream prefix is not the `BTRD` magic — garbage or a stray
    /// protocol speaking on our port.
    BadMagic([u8; 4]),
    /// Declared body length exceeds the receiver's frame cap.
    Oversized { len: usize, max: usize },
    /// Unknown frame kind byte.
    BadKind(u8),
    /// Body shorter than its kind's fixed fields.
    Truncated { need: usize, have: usize },
    /// Byte that names no `MsgClass`.
    BadClass(u8),
    /// Broadcast / signature flag outside {0, 1}.
    BadFlag(u8),
    /// Sender id does not fit this platform's `usize`.
    BadPeer(u64),
    /// A session-MAC frame on a link that never negotiated MAC mode —
    /// there is no key to check it with.
    MacUnexpected,
    /// A plain envelope frame on a session-MAC link: every post-HELLO
    /// frame must be stream-authenticated, so an unMAC'd frame can only
    /// be injected bytes.
    MacMissing,
    /// The frame's HMAC does not verify under the link key.
    BadMac,
    /// The frame's sequence number is not the expected next one —
    /// a replayed, dropped or reordered frame on what TCP promises is an
    /// ordered stream.
    BadSeq { got: u64, want: u64 },
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::BadMagic(m) => write!(f, "bad frame magic {m:02x?}"),
            FrameError::Oversized { len, max } => {
                write!(f, "frame body of {len} bytes exceeds the {max}-byte cap")
            }
            FrameError::BadKind(k) => write!(f, "unknown frame kind {k}"),
            FrameError::Truncated { need, have } => {
                write!(f, "truncated frame body: need {need} bytes, have {have}")
            }
            FrameError::BadClass(c) => write!(f, "byte {c} names no message class"),
            FrameError::BadFlag(b) => write!(f, "flag byte {b} outside {{0, 1}}"),
            FrameError::BadPeer(p) => write!(f, "peer id {p} does not fit usize"),
            FrameError::MacUnexpected => {
                write!(f, "session-MAC frame on a link that did not negotiate MAC mode")
            }
            FrameError::MacMissing => {
                write!(f, "plain envelope frame on a session-MAC link")
            }
            FrameError::BadMac => write!(f, "frame MAC does not verify under the link key"),
            FrameError::BadSeq { got, want } => {
                write!(f, "frame sequence {got} where {want} was expected")
            }
        }
    }
}

/// A decoded frame: the roster handshake or a protocol envelope.
#[derive(Debug)]
pub enum Frame {
    Hello(Hello),
    Envelope(Envelope),
}

/// Handshake payload: who is on the other end of this link, at which
/// roster epoch it was admitted, and a link-bound nonce. The signature
/// (present whenever the cluster verifies signatures) covers the
/// domain-tagged (id, epoch, nonce) triple, so only the holder of the
/// roster key can claim a peer's link — and because the nonce is a hash
/// of the *entire roster document* plus the claimed (id, epoch) plus
/// the intended *receiver*, a HELLO captured from a different run,
/// roster, epoch — or from the same run's link to a different peer —
/// replays as garbage: the receiver recomputes the expected nonce and
/// rejects the stale claim before any envelope is read.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Hello {
    pub id: PeerId,
    /// Roster epoch at which this peer is admitted: the training step of
    /// its scheduled join, 0 for founding members. Acceptors reject a
    /// HELLO whose epoch differs from the peer's scheduled one.
    pub epoch: u64,
    /// Link-bound nonce: `H("btard-hello-nonce" ‖ roster digest ‖ id ‖
    /// epoch ‖ receiver)` — see [`Roster::hello_nonce`].
    pub nonce: [u8; 32],
    pub pubkey: PublicKey,
    /// Whether the sender will stream-authenticate this link with the
    /// negotiated session MAC instead of signing every envelope. The
    /// flag is covered by the HELLO signature, so a man-in-the-middle
    /// cannot strip it to downgrade the link to unauthenticated frames.
    pub mac: bool,
    pub signature: Option<Signature>,
}

/// The byte string a HELLO's signature covers. Includes the session-MAC
/// negotiation flag: the mode must not be downgradable in flight.
fn hello_signing_bytes(id: PeerId, epoch: u64, nonce: &[u8; 32], mac: bool) -> Vec<u8> {
    let mut msg = Vec::with_capacity(11 + 8 + 8 + 32 + 1);
    msg.extend_from_slice(b"btard-hello");
    msg.extend_from_slice(&(id as u64).to_le_bytes());
    msg.extend_from_slice(&epoch.to_le_bytes());
    msg.extend_from_slice(nonce);
    msg.push(mac as u8);
    msg
}

/// Encode a HELLO frame (header + body) for the link `id → to` of this
/// roster, signed with the sender's roster key when `sign_hello` (i.e.
/// the cluster verifies signatures).
pub fn encode_hello(
    id: PeerId,
    epoch: u64,
    to: PeerId,
    roster_digest: &[u8; 32],
    secret: &SecretKey,
    mont: &Mont,
    mac: bool,
    sign_hello: bool,
) -> Vec<u8> {
    let nonce = Roster::hello_nonce_from(roster_digest, id, epoch, to);
    let sig_len = if sign_hello { 64 } else { 0 };
    let body_len = HELLO_FIXED + sig_len;
    let mut out = Vec::with_capacity(8 + body_len);
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&(body_len as u32).to_le_bytes());
    out.push(KIND_HELLO);
    out.extend_from_slice(&(id as u64).to_le_bytes());
    out.extend_from_slice(&epoch.to_le_bytes());
    out.extend_from_slice(&nonce);
    out.extend_from_slice(&secret.public.0);
    out.push(mac as u8);
    if sign_hello {
        out.push(1);
        out.extend_from_slice(
            &sign(mont, secret, &hello_signing_bytes(id, epoch, &nonce, mac)).to_bytes(),
        );
    } else {
        out.push(0);
    }
    out
}

/// The wire fields of an envelope — everything after the frame's kind
/// byte: `from ‖ step ‖ slot ‖ class ‖ broadcast ‖ sig flag [‖ sig] ‖
/// payload`. Shared by plain and session-MAC envelope frames, so a
/// broadcast encodes its O(d) payload once and only the tiny per-link
/// prefix differs.
fn envelope_fields(env: &Envelope) -> Vec<u8> {
    let sig_len = if env.signature.is_some() { 64 } else { 0 };
    let mut out = Vec::with_capacity(ENVELOPE_FIXED - 1 + sig_len + env.payload.len());
    out.extend_from_slice(&(env.from as u64).to_le_bytes());
    out.extend_from_slice(&env.step.to_le_bytes());
    out.extend_from_slice(&env.slot.to_le_bytes());
    out.push(env.class as u8);
    out.push(env.broadcast as u8);
    match &env.signature {
        Some(sig) => {
            out.push(1);
            out.extend_from_slice(&sig.to_bytes());
        }
        None => out.push(0),
    }
    out.extend_from_slice(&env.payload);
    out
}

/// Encode an envelope frame (header + body). `deliver_at` is routing
/// metadata stamped by the *receiving* transport, never serialized.
pub fn encode_envelope(env: &Envelope) -> Vec<u8> {
    let fields = envelope_fields(env);
    let body_len = 1 + fields.len();
    assert!(body_len <= u32::MAX as usize, "envelope payload too large for the frame codec");
    let mut out = Vec::with_capacity(8 + body_len);
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&(body_len as u32).to_le_bytes());
    out.push(KIND_ENVELOPE);
    out.extend_from_slice(&fields);
    out
}

/// The stream MAC of a session-MAC frame: HMAC over the link's
/// per-direction counter and the envelope fields, under the link key.
/// The counter makes every frame's MAC unique, so a captured frame
/// cannot be replayed later in the same stream.
fn frame_mac(key: &[u8; 32], seq: u64, fields: &[u8]) -> [u8; 32] {
    hmac_sha256(key, &[b"btard-mac-frame", &seq.to_le_bytes(), fields])
}

/// Frame header + `kind ‖ seq ‖ mac` prefix for a session-MAC envelope
/// frame whose fields follow (written separately, so broadcasts share
/// one fields buffer across recipients).
fn mac_frame_prefix(fields: &[u8], seq: u64, key: &[u8; 32]) -> Vec<u8> {
    mac_frame_prefix_with(fields.len(), &seq.to_le_bytes(), &frame_mac(key, seq, fields))
}

/// Assemble a session-MAC frame prefix from an already-computed MAC —
/// the broadcast path computes MACs for all links in one batched
/// multi-buffer HMAC sweep and then builds each prefix from its digest.
fn mac_frame_prefix_with(fields_len: usize, seq_le: &[u8; 8], mac: &[u8; 32]) -> Vec<u8> {
    let body_len = MAC_FIXED + fields_len;
    assert!(body_len <= u32::MAX as usize, "envelope payload too large for the frame codec");
    let mut out = Vec::with_capacity(8 + MAC_FIXED);
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&(body_len as u32).to_le_bytes());
    out.push(KIND_MAC_ENVELOPE);
    out.extend_from_slice(seq_le);
    out.extend_from_slice(mac);
    out
}

/// Frame header + kind prefix for a plain (no session MAC) envelope
/// frame whose fields follow.
fn plain_frame_prefix(fields_len: usize) -> Vec<u8> {
    let body_len = 1 + fields_len;
    assert!(body_len <= u32::MAX as usize, "envelope payload too large for the frame codec");
    let mut out = Vec::with_capacity(9);
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&(body_len as u32).to_le_bytes());
    out.push(KIND_ENVELOPE);
    out
}

/// Encode a complete session-MAC envelope frame (tests and single-frame
/// paths; the send path writes prefix and fields separately).
pub(crate) fn encode_mac_envelope(env: &Envelope, seq: u64, key: &[u8; 32]) -> Vec<u8> {
    let fields = envelope_fields(env);
    let mut out = mac_frame_prefix(&fields, seq, key);
    out.extend_from_slice(&fields);
    out
}

/// Directional link key for a session-MAC link: derived from the pair's
/// static-static DH shared secret, the (sender, receiver) direction and
/// the roster digest, so the two directions of a link never share a key
/// and a key from one run's roster is garbage under another's.
fn link_mac_key(shared: &[u8; 32], from: PeerId, to: PeerId, roster_digest: &[u8; 32]) -> [u8; 32] {
    hmac_sha256(
        shared,
        &[
            b"btard-mac-key",
            &(from as u64).to_le_bytes(),
            &(to as u64).to_le_bytes(),
            roster_digest,
        ],
    )
}

fn le_u64(b: &[u8]) -> u64 {
    u64::from_le_bytes(b[..8].try_into().unwrap())
}

/// Decode the envelope fields of a frame body — the bytes after the
/// kind byte of a `KIND_ENVELOPE` frame, or after the `kind ‖ seq ‖ mac`
/// prefix of a `KIND_MAC_ENVELOPE` frame.
fn decode_envelope_fields(b: &[u8]) -> Result<Envelope, FrameError> {
    const FIELDS_FIXED: usize = ENVELOPE_FIXED - 1;
    if b.len() < FIELDS_FIXED {
        return Err(FrameError::Truncated { need: FIELDS_FIXED, have: b.len() });
    }
    let from = le_u64(&b[0..8]);
    let from: PeerId = usize::try_from(from).map_err(|_| FrameError::BadPeer(from))?;
    let step = le_u64(&b[8..16]);
    let slot = u32::from_le_bytes(b[16..20].try_into().unwrap());
    let class = MsgClass::from_u8(b[20]).ok_or(FrameError::BadClass(b[20]))?;
    let broadcast = match b[21] {
        0 => false,
        1 => true,
        f => return Err(FrameError::BadFlag(f)),
    };
    let (signature, payload_at) = match b[22] {
        0 => (None, FIELDS_FIXED),
        1 => {
            let end = FIELDS_FIXED + 64;
            if b.len() < end {
                return Err(FrameError::Truncated { need: end, have: b.len() });
            }
            (Signature::from_bytes(&b[FIELDS_FIXED..end]), end)
        }
        f => return Err(FrameError::BadFlag(f)),
    };
    Ok(Envelope {
        from,
        step,
        slot,
        class,
        payload: b[payload_at..].to_vec().into(),
        broadcast,
        deliver_at: 0,
        signature,
    })
}

fn decode_body(body: &[u8]) -> Result<Frame, FrameError> {
    let kind = *body.first().ok_or(FrameError::Truncated { need: 1, have: 0 })?;
    match kind {
        KIND_HELLO => {
            if body.len() < HELLO_FIXED {
                return Err(FrameError::Truncated { need: HELLO_FIXED, have: body.len() });
            }
            let id = le_u64(&body[1..9]);
            let id: PeerId = usize::try_from(id).map_err(|_| FrameError::BadPeer(id))?;
            let epoch = le_u64(&body[9..17]);
            let mut nonce = [0u8; 32];
            nonce.copy_from_slice(&body[17..49]);
            let mut pk = [0u8; 32];
            pk.copy_from_slice(&body[49..81]);
            let mac = match body[81] {
                0 => false,
                1 => true,
                b => return Err(FrameError::BadFlag(b)),
            };
            let signature = match body[82] {
                0 if body.len() == HELLO_FIXED => None,
                1 if body.len() == HELLO_FIXED + 64 => {
                    Signature::from_bytes(&body[HELLO_FIXED..HELLO_FIXED + 64])
                }
                0 | 1 => {
                    return Err(FrameError::Truncated {
                        need: HELLO_FIXED + 64 * body[82] as usize,
                        have: body.len(),
                    })
                }
                b => return Err(FrameError::BadFlag(b)),
            };
            Ok(Frame::Hello(Hello { id, epoch, nonce, pubkey: PublicKey(pk), mac, signature }))
        }
        KIND_ENVELOPE => Ok(Frame::Envelope(decode_envelope_fields(&body[1..])?)),
        // Session-MAC frames need the link key and counter — they are
        // handled by `FrameReader::next_frame` before this fallback.
        KIND_MAC_ENVELOPE => Err(FrameError::MacUnexpected),
        k => Err(FrameError::BadKind(k)),
    }
}

/// Incremental frame decoder: feed it whatever the socket hands you —
/// one byte at a time, half a frame, three frames at once — and pull
/// complete frames out. Oversized length prefixes are rejected *before*
/// the body is buffered; every decode error is connection-fatal (a TCP
/// stream with a corrupt frame has no resynchronization point).
pub struct FrameReader {
    buf: Vec<u8>,
    max_frame: usize,
    /// Session-MAC receive state, installed after a handshake that
    /// negotiated MAC mode. Once set, every envelope frame must be a
    /// MAC frame with the expected next sequence number — a plain frame
    /// can only be injected bytes and kills the link.
    mac: Option<MacRecv>,
}

/// Per-link session-MAC receive state: the directional link key and the
/// strictly-incrementing expected frame counter (TCP delivers in order,
/// so any gap or repeat is tampering, not reordering).
struct MacRecv {
    key: [u8; 32],
    next_seq: u64,
}

/// Per-link session-MAC send state (the mirror of [`MacRecv`]).
struct MacSend {
    key: [u8; 32],
    next_seq: u64,
}

impl FrameReader {
    pub fn new(max_frame: usize) -> FrameReader {
        FrameReader { buf: Vec::new(), max_frame, mac: None }
    }

    /// Install the link's session-MAC key (called once, right after a
    /// handshake that negotiated MAC mode). Frames already buffered —
    /// the sender may pipeline envelopes behind its HELLO — are decoded
    /// under the MAC from the stream's first envelope frame onward.
    pub(crate) fn enable_mac(&mut self, key: [u8; 32]) {
        self.mac = Some(MacRecv { key, next_seq: 0 });
    }

    pub fn feed(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Next complete frame, `Ok(None)` if more bytes are needed.
    pub fn next_frame(&mut self) -> Result<Option<Frame>, FrameError> {
        if self.buf.len() < 8 {
            return Ok(None);
        }
        if self.buf[..4] != MAGIC {
            return Err(FrameError::BadMagic(self.buf[..4].try_into().unwrap()));
        }
        let len = u32::from_le_bytes(self.buf[4..8].try_into().unwrap()) as usize;
        if len > self.max_frame {
            return Err(FrameError::Oversized { len, max: self.max_frame });
        }
        if self.buf.len() < 8 + len {
            return Ok(None);
        }
        let body = &self.buf[8..8 + len];
        let frame = match body.first() {
            Some(&KIND_MAC_ENVELOPE) => {
                let mac = self.mac.as_mut().ok_or(FrameError::MacUnexpected)?;
                if body.len() < MAC_FIXED {
                    return Err(FrameError::Truncated { need: MAC_FIXED, have: body.len() });
                }
                let seq = le_u64(&body[1..9]);
                if seq != mac.next_seq {
                    return Err(FrameError::BadSeq { got: seq, want: mac.next_seq });
                }
                let fields = &body[MAC_FIXED..];
                if body[9..41] != frame_mac(&mac.key, seq, fields) {
                    return Err(FrameError::BadMac);
                }
                mac.next_seq += 1;
                Frame::Envelope(decode_envelope_fields(fields)?)
            }
            Some(&KIND_ENVELOPE) if self.mac.is_some() => return Err(FrameError::MacMissing),
            _ => decode_body(body)?,
        };
        self.buf.drain(..8 + len);
        Ok(Some(frame))
    }
}

// ---------------------------------------------------------------------------
// Roster
// ---------------------------------------------------------------------------

/// One roster row: who a peer is and where it listens.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RosterEntry {
    pub id: PeerId,
    /// `host:port` the peer's listener is bound to.
    pub addr: String,
    pub pubkey: PublicKey,
}

/// The cluster roster: the one artifact socket peers share out of band.
/// Ids must be the contiguous range `0..n` (they index the partition
/// map, the ban ledger and the signature table, exactly like in-process
/// peer ids).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Roster {
    pub peers: Vec<RosterEntry>,
}

impl Roster {
    pub fn n(&self) -> usize {
        self.peers.len()
    }

    /// Parse and validate a roster JSON document:
    /// `{"peers": [{"id": 0, "addr": "127.0.0.1:9000", "pubkey": "<64 hex>"}, …]}`.
    pub fn parse(text: &str) -> Result<Roster, String> {
        let j = Json::parse(text)?;
        let arr = j
            .get("peers")
            .and_then(|v| v.as_arr())
            .ok_or("roster must be an object with a 'peers' array")?;
        let mut peers = Vec::with_capacity(arr.len());
        for p in arr {
            let id = p
                .get("id")
                .and_then(|v| v.as_usize())
                .ok_or("roster entry missing integer 'id'")?;
            let addr = p
                .get("addr")
                .and_then(|v| v.as_str())
                .ok_or("roster entry missing string 'addr'")?
                .to_string();
            if addr.is_empty() {
                return Err(format!("roster entry {id} has an empty addr"));
            }
            let pk_hex = p
                .get("pubkey")
                .and_then(|v| v.as_str())
                .ok_or("roster entry missing string 'pubkey'")?;
            let pk = unhex(pk_hex)
                .filter(|b| b.len() == 32)
                .ok_or_else(|| format!("roster entry {id}: pubkey must be 64 hex chars"))?;
            let mut key = [0u8; 32];
            key.copy_from_slice(&pk);
            peers.push(RosterEntry { id, addr, pubkey: PublicKey(key) });
        }
        if peers.len() < 2 {
            return Err("roster needs at least 2 peers".to_string());
        }
        peers.sort_by_key(|p| p.id);
        for (k, p) in peers.iter().enumerate() {
            if p.id != k {
                return Err(format!(
                    "roster ids must be the contiguous range 0..{} (missing or duplicate id {k})",
                    peers.len()
                ));
            }
        }
        Ok(Roster { peers })
    }

    pub fn to_json(&self) -> String {
        let peers: Vec<Json> = self
            .peers
            .iter()
            .map(|p| {
                Json::obj(vec![
                    ("id", Json::num(p.id as f64)),
                    ("addr", Json::str(&p.addr)),
                    ("pubkey", Json::str(&hex(&p.pubkey.0))),
                ])
            })
            .collect();
        Json::obj(vec![("peers", Json::Arr(peers))]).to_string_pretty()
    }

    pub fn load(path: &std::path::Path) -> Result<Roster, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading roster '{}': {e}", path.display()))?;
        Roster::parse(&text)
    }

    /// Atomic save (tmp + rename): a reader polling for the file never
    /// observes a half-written roster.
    pub fn save(&self, path: &std::path::Path) -> std::io::Result<()> {
        crate::util::atomic_write(path, &self.to_json())
    }

    /// Digest over every roster row (id, addr, pubkey) — the identity of
    /// this roster document. Binding HELLOs to it is what makes a
    /// captured handshake from another run or roster unreplayable here.
    pub fn digest(&self) -> [u8; 32] {
        let mut bytes = Vec::new();
        for p in &self.peers {
            bytes.extend_from_slice(&(p.id as u64).to_le_bytes());
            bytes.extend_from_slice(&(p.addr.len() as u64).to_le_bytes());
            bytes.extend_from_slice(p.addr.as_bytes());
            bytes.extend_from_slice(&p.pubkey.0);
        }
        crate::crypto::sha256_parts(&[b"btard-roster", &bytes])
    }

    /// The roster-bound HELLO nonce for a (sender, epoch, receiver)
    /// link: a pure function both ends compute independently from the
    /// shared roster document. Binding the *receiver* is what stops a
    /// HELLO captured on one link of the same run from being replayed
    /// against any other peer's acceptor (a first-claim-wins inbound
    /// slot would otherwise be burnable by replay).
    pub fn hello_nonce(&self, id: PeerId, epoch: u64, to: PeerId) -> [u8; 32] {
        Roster::hello_nonce_from(&self.digest(), id, epoch, to)
    }

    /// Same, from a pre-computed roster digest — the roster is immutable
    /// for a run, so endpoints hash it once instead of once per HELLO
    /// encode and once per inbound handshake.
    pub fn hello_nonce_from(
        roster_digest: &[u8; 32],
        id: PeerId,
        epoch: u64,
        to: PeerId,
    ) -> [u8; 32] {
        crate::crypto::sha256_parts(&[
            b"btard-hello-nonce",
            roster_digest,
            &(id as u64).to_le_bytes(),
            &epoch.to_le_bytes(),
            &(to as u64).to_le_bytes(),
        ])
    }
}

/// Deterministic per-peer keypair of a run: the exact derivation the
/// in-process cluster builder uses (`build_cluster` with
/// `key_seed = run_seed ^ 0xC1A5`). Deriving instead of generating is
/// what makes a socket run's signatures — and therefore its metrics
/// digest — bit-identical to the in-process run of the same seed.
/// Simulation-grade by design; a production roster would carry fresh
/// independently-generated keys.
pub fn derive_keypair(mont: &Mont, run_seed: u64, id: PeerId) -> SecretKey {
    keygen(mont, (run_seed ^ 0xC1A5) + id as u64)
}

/// Bind an ephemeral loopback listener, returning it with its concrete
/// `host:port` (the rendezvous flow publishes this in an addr file).
pub fn bind_ephemeral() -> std::io::Result<(TcpListener, String)> {
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    Ok((listener, addr))
}

// ---------------------------------------------------------------------------
// SocketNet
// ---------------------------------------------------------------------------

/// Socket-level knobs (the protocol-level ones stay in `RunConfig`).
#[derive(Clone, Debug)]
pub struct SocketConfig {
    /// Overlay out-degree cap in gossip mode (effective out-degree is
    /// `min(fanout, ⌈log₂ n⌉)` — see [`Overlay::derive`]). Ignored by
    /// the full-mesh dissemination mode.
    pub gossip_fanout: u64,
    /// Route broadcast traffic through the deterministic gossip overlay
    /// instead of writing every broadcast to every peer: per-peer link
    /// count and broadcast wire bytes drop from O(n) to
    /// O(fanout·log n). Point-to-point slots dial direct links lazily
    /// either way.
    pub gossip: bool,
    /// Roster timeline for the overlay, one entry per membership epoch:
    /// `(first step, live peer set)`, first entry at step 0. Empty means
    /// a single static epoch of all founding members. Pure config data —
    /// every peer derives the identical overlay schedule from it.
    pub overlay_epochs: Vec<(u64, Vec<PeerId>)>,
    /// Seed the overlay derivation mixes in (the run seed, so different
    /// runs relay along different graphs).
    pub overlay_seed: u64,
    pub verify_signatures: bool,
    /// Negotiate per-link session MACs after the signed HELLO: bulk
    /// payload frames (`GRAD_PART` / `AGG_PART`) ride an HMAC-SHA256
    /// stream MAC keyed from a static-static DH shared secret, while
    /// every slot that can appear in an adjudication transcript keeps
    /// its transferable Schnorr signature (see [`super::auth`]).
    /// Requires `verify_signatures` — the signed HELLO is what makes
    /// the MAC negotiation downgrade-proof.
    pub session_mac: bool,
    /// Budget for the whole mesh build: dial retries, accepts and both
    /// HELLO exchanges must finish within it.
    pub connect_timeout: Duration,
    pub max_frame: usize,
    /// Per-peer join step over the whole universe (0 = founding member;
    /// empty = all founding). This is the *effective* churn schedule's
    /// `join_steps(n)` table — under consensus admission the caller
    /// derives it from the candidate petitions (see
    /// [`crate::coordinator::consensus::AdmissionConfig::derived_schedule`]),
    /// so a petitioning candidate looks exactly like a scheduled joiner
    /// down here: the table decides which links form at mesh-build time
    /// vs lazily at the peer's epoch boundary, gates wire sends to
    /// not-yet-admitted peers, and is the epoch an inbound HELLO must
    /// claim to be accepted. Whether the candidate is actually admitted
    /// is the protocol plane's call (the roster certificate), not the
    /// transport's.
    pub join_steps: Vec<u64>,
    /// Per-peer scheduled crash step (`None` = never crashes; empty =
    /// nobody does). During a peer's `[crash, rejoin)` window wire
    /// sends to it are suppressed exactly like pre-join traffic — the
    /// in-process fabrics deliver-and-discard instead, which is
    /// observably identical (the peer drops the window's traffic at
    /// snapshot install either way).
    pub crash_steps: Vec<Option<u64>>,
    /// Per-peer scheduled rejoin step. A peer with a rejoin step may
    /// legitimately come back from the dead: its inbound HELLO may
    /// claim the rejoin epoch, a condemned outbound link to it revives
    /// on the first post-rejoin send, and its fresh address is looked
    /// up through `rejoin_addr_dir`.
    pub rejoin_steps: Vec<Option<u64>>,
    /// This endpoint is the restarted second life of a crashed peer: it
    /// announces itself with its *rejoin* epoch in every HELLO and
    /// builds no founding links (everything forms lazily, like a late
    /// joiner).
    pub restarted: bool,
    /// Where restarted peers publish their fresh listen address as
    /// `addr_<id>.rejoin` (the roster row still holds the first life's
    /// port, which the OS may hold in TIME_WAIT). Incumbents re-resolve
    /// a rejoin-scheduled peer's address from this directory when they
    /// revive its link.
    pub rejoin_addr_dir: Option<std::path::PathBuf>,
    /// Cap on one outbound link's unflushed byte backlog before the
    /// link is condemned (see [`DEFAULT_MAX_LINK_BACKLOG`]).
    pub max_link_backlog: usize,
}

impl Default for SocketConfig {
    fn default() -> Self {
        SocketConfig {
            gossip_fanout: 8,
            gossip: false,
            overlay_epochs: vec![],
            overlay_seed: 0,
            verify_signatures: true,
            session_mac: false,
            connect_timeout: Duration::from_secs(30),
            max_frame: DEFAULT_MAX_FRAME,
            join_steps: vec![],
            crash_steps: vec![],
            rejoin_steps: vec![],
            restarted: false,
            rejoin_addr_dir: None,
            max_link_backlog: DEFAULT_MAX_LINK_BACKLOG,
        }
    }
}

/// Whether frames for `to` at `step` belong on the wire: not before the
/// peer's scheduled join, and not during its scheduled crash window.
/// The in-process fabrics deliver-and-discard instead, which is
/// observably identical — the peer drops the window's traffic at
/// snapshot install either way.
fn wire_admitted(
    join_steps: &[u64],
    crash_steps: &[Option<u64>],
    rejoin_steps: &[Option<u64>],
    to: PeerId,
    step: u64,
) -> bool {
    if step < join_steps[to] {
        return false;
    }
    match (crash_steps[to], rejoin_steps[to]) {
        (Some(c), Some(r)) => step < c || step >= r,
        (Some(c), None) => step < c,
        _ => true,
    }
}

fn io_err(msg: String) -> std::io::Error {
    std::io::Error::new(ErrorKind::InvalidData, msg)
}

fn timeout_err(what: &str) -> std::io::Error {
    std::io::Error::new(ErrorKind::TimedOut, format!("socket mesh: timed out {what}"))
}

/// Dial with retry until the deadline: the target may not have bound its
/// listener yet (peers start in arbitrary order). Each attempt uses
/// `connect_timeout` bounded by the time left — a roster address behind
/// a packet-dropping firewall must fail at the configured deadline, not
/// after the OS's multi-minute default SYN timeout.
fn dial_with_retry(addr: &str, deadline: Instant) -> std::io::Result<TcpStream> {
    const ATTEMPT_CAP: Duration = Duration::from_secs(2);
    loop {
        let remaining = deadline.saturating_duration_since(Instant::now());
        if remaining.is_zero() {
            return Err(std::io::Error::new(
                ErrorKind::TimedOut,
                format!("dialing {addr}: deadline exceeded"),
            ));
        }
        let attempt = addr
            .to_socket_addrs()
            .and_then(|mut addrs| {
                addrs.next().ok_or_else(|| io_err(format!("'{addr}' resolves to no address")))
            })
            .and_then(|sa| TcpStream::connect_timeout(&sa, remaining.min(ATTEMPT_CAP)));
        match attempt {
            Ok(s) => return Ok(s),
            Err(e) => {
                if Instant::now() >= deadline {
                    return Err(std::io::Error::new(
                        ErrorKind::TimedOut,
                        format!("dialing {addr}: {e}"),
                    ));
                }
                thread::sleep(Duration::from_millis(30));
            }
        }
    }
}

/// Read one frame before the deadline, leaving any extra bytes in `fr`
/// (the remote may pipeline envelopes right behind its HELLO — those
/// bytes belong to the link's reader thread, which inherits `fr`).
fn read_frame_deadline(
    stream: &mut TcpStream,
    fr: &mut FrameReader,
    deadline: Instant,
) -> std::io::Result<Frame> {
    let mut buf = [0u8; 4096];
    loop {
        if let Some(frame) = fr.next_frame().map_err(|e| io_err(e.to_string()))? {
            return Ok(frame);
        }
        let remaining = deadline.saturating_duration_since(Instant::now());
        if remaining.is_zero() {
            return Err(timeout_err("waiting for a handshake frame"));
        }
        stream.set_read_timeout(Some(remaining))?;
        match stream.read(&mut buf) {
            Ok(0) => {
                return Err(std::io::Error::new(
                    ErrorKind::UnexpectedEof,
                    "connection closed during handshake",
                ))
            }
            Ok(k) => fr.feed(&buf[..k]),
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                return Err(timeout_err("waiting for a handshake frame"))
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
}

/// Per-connection slice of the accept loop's budget: a silent or
/// garbage inbound connection (port scanner, health probe, hostile
/// peer) is dropped after at most this long. Handshakes run on their
/// own threads, so a stalling connection costs only itself — never the
/// mesh build (see `accept_handshake`).
const HELLO_SLICE: Duration = Duration::from_secs(5);

/// Validate one inbound connection's HELLO against the roster. Errors
/// here condemn the *connection*, not the accept loop: the module
/// contract is that a hostile peer can kill its own link, never the
/// receiver — aborting the whole mesh build on a stray probe would hand
/// any port-scanner a denial of service. Checks, in order: the claimed
/// id is a valid remote; the claimed epoch is exactly the peer's
/// scheduled join epoch (`join_steps`) — a *stale* HELLO (wrong epoch,
/// e.g. a replay from before a roster change) is rejected outright; the
/// nonce matches the roster-bound derivation for (id, epoch) — a HELLO
/// captured from a different run or roster document replays as garbage;
/// the pubkey matches the roster row; and (when the cluster verifies
/// signatures) the signature over the domain-tagged (id, epoch, nonce)
/// verifies under the roster key — an unsigned or mis-signed identity
/// claim is exactly the spoof this check exists to stop.
fn accept_handshake(
    stream: &mut TcpStream,
    fr: &mut FrameReader,
    deadline: Instant,
    me: PeerId,
    roster: &Roster,
    roster_digest: &[u8; 32],
    join_steps: &[u64],
    rejoin_steps: &[Option<u64>],
    mont: &Mont,
    verify_signatures: bool,
    session_mac: bool,
) -> Result<Hello, String> {
    let frame = read_frame_deadline(stream, fr, deadline).map_err(|e| e.to_string())?;
    let h = match frame {
        Frame::Hello(h) => h,
        Frame::Envelope(_) => return Err("envelope before HELLO".to_string()),
    };
    if h.id == me || h.id >= roster.n() {
        return Err(format!("HELLO claims peer {} (not a valid remote of peer {me})", h.id));
    }
    let expected_epoch = join_steps.get(h.id).copied().unwrap_or(0);
    // A crash-scheduled peer's restarted second life legitimately
    // announces itself at its *rejoin* epoch — both admissions are
    // schedule data, so both are acceptable claims; anything else is a
    // stale replay.
    let rejoin_epoch = rejoin_steps.get(h.id).copied().flatten();
    if h.epoch != expected_epoch && Some(h.epoch) != rejoin_epoch {
        return Err(format!(
            "stale HELLO: peer {} claims roster epoch {} but is scheduled at epoch \
             {expected_epoch}{}",
            h.id,
            h.epoch,
            rejoin_epoch.map(|r| format!(" (rejoin epoch {r})")).unwrap_or_default()
        ));
    }
    if h.nonce != Roster::hello_nonce_from(roster_digest, h.id, h.epoch, me) {
        return Err(format!(
            "HELLO nonce for peer {} is not bound to this roster+link (replayed from another \
             run, roster, or link?)",
            h.id
        ));
    }
    if h.pubkey != roster.peers[h.id].pubkey {
        return Err(format!("HELLO pubkey for peer {} does not match the roster", h.id));
    }
    if verify_signatures {
        let Some(sig) = &h.signature else {
            return Err(format!("unsigned HELLO claiming peer {}", h.id));
        };
        let msg = hello_signing_bytes(h.id, h.epoch, &h.nonce, h.mac);
        if !verify(mont, &roster.peers[h.id].pubkey, &msg, sig) {
            return Err(format!("HELLO signature for peer {} does not verify", h.id));
        }
    }
    // Both ends must agree on the link's authentication mode. The mac
    // flag is covered by the HELLO signature (verified above), so a
    // man-in-the-middle cannot strip the flag to downgrade a MAC link
    // to unauthenticated plain frames.
    if h.mac != session_mac {
        return Err(format!(
            "HELLO from peer {} negotiates session_mac={} but this endpoint runs \
             session_mac={session_mac}",
            h.id, h.mac
        ));
    }
    Ok(h)
}

/// Transport-level frame admission on an authenticated link: only
/// envelope frames whose `from` matches the link's peer pass. Everything
/// else — a second HELLO, a spoofed sender — is a protocol violation
/// that kills the link (returns `None`).
pub(crate) fn admit_frame(frame: Frame, link_peer: PeerId) -> Option<Envelope> {
    match frame {
        Frame::Envelope(env) if env.from == link_peer => Some(env),
        _ => None,
    }
}

// ---------------------------------------------------------------------------
// The event-loop engine
// ---------------------------------------------------------------------------
//
// One I/O thread per endpoint owns every link: the listener, all inbound
// (receive-only) connections, all outbound (send-only) connections, the
// gossip relay state and every session-MAC send counter. The driver
// thread signs envelopes and queues `IoCmd`s; the loop multiplexes the
// sockets with poll(2). Replacing the per-link reader threads, this is
// what keeps a 512-peer loopback cluster inside the thread budget:
// threads are O(1) per endpoint, not O(n).

// poll(2), declared directly — the crate is std-only (no libc). `nfds_t`
// is C `unsigned long`, i.e. u64 on every 64-bit Unix this targets.
#[repr(C)]
struct PollFd {
    fd: i32,
    events: i16,
    revents: i16,
}

const POLLIN: i16 = 0x001;
const POLLOUT: i16 = 0x004;

extern "C" {
    fn poll(fds: *mut PollFd, nfds: u64, timeout_ms: i32) -> i32;
}

/// EINTR-retrying poll(2). HUP/ERR conditions surface through `revents`
/// of the fd they hit; the loop handles them by attempting the I/O and
/// observing the failure.
fn poll_fds(fds: &mut [PollFd], timeout_ms: i32) -> usize {
    loop {
        let rc = unsafe { poll(fds.as_mut_ptr(), fds.len() as u64, timeout_ms) };
        if rc >= 0 {
            return rc as usize;
        }
        let err = std::io::Error::last_os_error();
        if err.kind() != ErrorKind::Interrupted {
            // EFAULT/EINVAL would be a bug; degrade to a timed sleep
            // rather than spinning hot on the error.
            thread::sleep(Duration::from_millis(10));
            return 0;
        }
    }
}

/// Wakes the event loop out of poll(2): one byte down a socketpair the
/// loop always polls. Both ends are non-blocking — a full pipe means a
/// wake is already pending, which is all a waker must guarantee.
struct LoopWaker {
    tx: UnixStream,
}

impl LoopWaker {
    fn wake(&self) {
        let _ = (&self.tx).write(&[1u8]);
    }
}

/// Commands the driver half (and its short-lived helper threads) queue
/// for the I/O loop, each paired with a `LoopWaker` poke.
enum IoCmd {
    /// Write one point-to-point envelope frame (lazy-dialing the link).
    /// `step` is the envelope's protocol step — what decides whether a
    /// condemned link to a rejoin-scheduled peer gets a fresh start.
    Send { to: PeerId, step: u64, fields: Vec<u8> },
    /// Disseminate a broadcast this endpoint originated: full mesh
    /// writes it to every admitted peer, gossip mode to the overlay
    /// out-neighbours (pre-marking `digest` so echoes are not re-relayed).
    Broadcast { step: u64, slot: u32, digest: [u8; 32], fields: Vec<u8> },
    /// A handshake thread validated an inbound connection.
    Inbound { peer: PeerId, stream: TcpStream, fr: FrameReader },
    /// A dial thread finished a lazy outbound connect.
    DialDone { to: PeerId, result: Result<TcpStream, String> },
    /// Begin teardown: flush what a bounded budget allows, FIN every
    /// outbound link, close every inbound link, exit.
    Shutdown,
}

/// Link bookkeeping shared between the loop and the driver: the mesh
/// build blocks on expected inbound links, and benches read open-link
/// counts (the overlay's point is that they stay O(fanout), not O(n)).
struct LinkGauge {
    state: Mutex<GaugeState>,
    cond: Condvar,
}

struct GaugeState {
    /// Peers that have (ever) had an inbound link installed — first
    /// claim wins, so this never un-sets.
    seen_in: Vec<bool>,
    open_in: usize,
    open_out: usize,
}

impl LinkGauge {
    fn new(n: usize) -> LinkGauge {
        LinkGauge {
            state: Mutex::new(GaugeState { seen_in: vec![false; n], open_in: 0, open_out: 0 }),
            cond: Condvar::new(),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, GaugeState> {
        self.state.lock().unwrap_or_else(|p| p.into_inner())
    }
}

/// Outbound (send-only) link lifecycle. Nothing is ever read from these
/// sockets — the unidirectional-link rationale in the module docs.
enum OutLink {
    /// Never dialed: a lazy point-to-point link, or an overlay
    /// non-neighbour.
    Absent,
    /// A dial thread is in flight; frames queue behind the HELLO.
    Dialing { queued: Vec<u8> },
    /// Connected (non-blocking); unflushed bytes wait for POLLOUT.
    Open { stream: TcpStream, pending: Vec<u8>, sent: usize },
    /// One failed dial or write marks the link dead for good (the
    /// protocol's timeout/ELIMINATE machinery owns unreachable peers).
    Dead,
}

struct InLink {
    stream: TcpStream,
    fr: FrameReader,
}

/// Gossip-mode state: the per-epoch overlays (a pure function of
/// config, identical at every peer) and the relay-once tracker.
struct RelayState {
    schedule: OverlaySchedule,
    tracker: RelayTracker,
    /// High-water step, for GC'ing the tracker.
    max_step: u64,
}

/// What each pollfd the loop registered refers to.
enum FdTag {
    Waker,
    Listener,
    In(PeerId),
    Out(PeerId),
}

/// How many steps relay-tracker entries outlive their step (matches the
/// inbox's tolerance for stragglers; bounds tracker memory).
const RELAY_GC_HORIZON: u64 = 8;

/// Teardown grace: how long the loop keeps flushing queued outbound
/// bytes after `Shutdown` before closing the links anyway.
const SHUTDOWN_FLUSH_BUDGET: Duration = Duration::from_secs(5);

/// Everything a handshake thread needs to validate one inbound
/// connection on its own and hand the authenticated link to the event
/// loop.
struct HandshakeCtx {
    me: PeerId,
    roster: Roster,
    /// Cached — the roster is immutable for the run, so handshakes must
    /// not re-hash the whole document per inbound connection.
    roster_digest: [u8; 32],
    join_steps: Vec<u64>,
    rejoin_steps: Vec<Option<u64>>,
    verify_signatures: bool,
    /// Negotiated link-auth mode: every inbound HELLO must claim the
    /// same mode, and accepted links get their directional MAC key
    /// installed before the reader starts.
    session_mac: bool,
    /// Our long-term secret — session-MAC links derive their key from
    /// the static-static DH shared secret with the link peer.
    secret: SecretKey,
    max_frame: usize,
    cmd_tx: Sender<IoCmd>,
    waker: Arc<LoopWaker>,
}

/// Validate an inbound connection's HELLO on a short-lived thread and,
/// on success, hand the authenticated link to the event loop
/// (`IoCmd::Inbound`). A silent, garbage or stale connection burns only
/// its own HELLO_SLICE — never the accept path (stray probes must not
/// be able to deny service).
fn spawn_handshake(ctx: Arc<HandshakeCtx>, stream: TcpStream, hard_deadline: Instant) {
    let hello_deadline = (Instant::now() + HELLO_SLICE).min(hard_deadline);
    let name = format!("sock-handshake-{}", ctx.me);
    let spawned = thread::Builder::new().name(name).spawn(move || {
        let mut stream = stream;
        let result = stream.set_nonblocking(false).map_err(|e| e.to_string()).and_then(|()| {
            let _ = stream.set_nodelay(true);
            let mont = Mont::new();
            let mut fr = FrameReader::new(ctx.max_frame);
            accept_handshake(
                &mut stream,
                &mut fr,
                hello_deadline,
                ctx.me,
                &ctx.roster,
                &ctx.roster_digest,
                &ctx.join_steps,
                &ctx.rejoin_steps,
                &mont,
                ctx.verify_signatures,
                ctx.session_mac,
            )
            .map(|h| {
                if ctx.session_mac {
                    // The link is now authenticated by the signed HELLO;
                    // derive the sender→us directional key and require a
                    // valid stream MAC on every envelope frame from here
                    // on (including any the sender pipelined behind its
                    // HELLO — they are still buffered inside `fr`).
                    let shared =
                        shared_secret(&mont, &ctx.secret, &ctx.roster.peers[h.id].pubkey);
                    fr.enable_mac(link_mac_key(&shared, h.id, ctx.me, &ctx.roster_digest));
                }
                (h, fr)
            })
        });
        match result {
            Ok((h, fr)) => {
                match ctx.cmd_tx.send(IoCmd::Inbound { peer: h.id, stream, fr }) {
                    Ok(()) => ctx.waker.wake(),
                    Err(send_err) => {
                        // The loop is gone (endpoint tore down while we
                        // shook hands); close the orphaned socket.
                        if let IoCmd::Inbound { stream, .. } = send_err.0 {
                            let _ = stream.shutdown(Shutdown::Both);
                        }
                    }
                }
            }
            Err(reason) => {
                // Doomed connection; keep accepting. A legitimate peer
                // lost here surfaces as a build/collect timeout.
                eprintln!("socket mesh (peer {}): dropping inbound connection: {reason}", ctx.me);
                let _ = stream.shutdown(Shutdown::Both);
            }
        }
    });
    if let Err(e) = spawned {
        eprintln!("socket mesh: spawning handshake thread: {e}");
    }
}

/// Wall-clock budget for a *late* (post-build) dial — a **single**
/// connect attempt, no retry loop: the target's listener has been up
/// since its process start, so a healthy link connects instantly, and a
/// dead peer (an exited leaver or banned attacker) must fail fast — on
/// loopback a refused connect returns in microseconds; retrying it for
/// seconds inside the send path would stall a joiner's boundary
/// broadcast long enough for incumbents to time it out. One failed dial
/// marks the link dead for good (the protocol's timeout/ELIMINATE
/// machinery handles a peer that never comes up).
const LATE_DIAL_BUDGET: Duration = Duration::from_secs(2);

/// Wall-clock budget for dialing a rejoin-scheduled peer: its restarted
/// process may still be binding its fresh listener and publishing the
/// `addr_<id>.rejoin` file when the first post-rejoin send fires, so
/// these dials retry instead of failing fast. Bounded well below the
/// rejoiner's own boundary-join snapshot wait.
const REJOIN_DIAL_BUDGET: Duration = Duration::from_secs(10);

/// One connect attempt with a bounded timeout (late dials only — the
/// mesh build keeps `dial_with_retry`, where the target may legitimately
/// not have bound its listener yet).
fn dial_once(addr: &str, timeout: Duration) -> std::io::Result<TcpStream> {
    let sa = addr
        .to_socket_addrs()?
        .next()
        .ok_or_else(|| io_err(format!("'{addr}' resolves to no address")))?;
    TcpStream::connect_timeout(&sa, timeout)
}

/// The endpoint's single I/O thread: owns every socket, every MAC send
/// counter, and (in gossip mode) the relay state. Commands arrive from
/// the driver over `cmd_rx`; everything else is poll(2)-driven.
struct IoLoop {
    me: PeerId,
    info: Arc<ClusterInfo>,
    listener: TcpListener,
    hs_ctx: Arc<HandshakeCtx>,
    cmd_rx: Receiver<IoCmd>,
    /// Cloned into dial threads so their completions re-enter the loop.
    cmd_tx: Sender<IoCmd>,
    waker: Arc<LoopWaker>,
    waker_rx: UnixStream,
    /// Delivery into the shared [`Inbox`].
    mailbox: Sender<Envelope>,
    /// Roster addresses (lazy dials need them mid-run).
    addrs: Vec<String>,
    /// Pre-encoded per-recipient HELLO frames (the nonce binds the
    /// link, so each recipient gets its own; empty at our own slot).
    hellos: Vec<Vec<u8>>,
    /// Per-peer join step (all zeros for a static roster).
    join_steps: Vec<u64>,
    /// Per-peer scheduled crash / rejoin steps (see [`SocketConfig`]).
    crash_steps: Vec<Option<u64>>,
    rejoin_steps: Vec<Option<u64>>,
    /// Where a restarted peer publishes its fresh listen address.
    rejoin_addr_dir: Option<std::path::PathBuf>,
    /// Backlog cap per outbound link (see `enforce_backlog`).
    max_link_backlog: usize,
    /// Per-recipient session-MAC send state (us→peer key + counter).
    /// Owned by the loop so relayed frames share the same per-link
    /// counters as our own sends — no counter races, no gaps.
    mac_send: Vec<Option<MacSend>>,
    out: Vec<OutLink>,
    inbound: Vec<Option<InLink>>,
    relay: Option<RelayState>,
    gauge: Arc<LinkGauge>,
}

impl IoLoop {
    fn run(mut self) {
        let mut running = true;
        let mut flush_deadline = Instant::now(); // set when Shutdown arrives
        let mut fds: Vec<PollFd> = Vec::new();
        let mut tags: Vec<FdTag> = Vec::new();
        loop {
            // Commands first: they may have queued while we were busy,
            // and handling them can arm new pollfds.
            loop {
                match self.cmd_rx.try_recv() {
                    Ok(cmd) => {
                        if matches!(cmd, IoCmd::Shutdown) && running {
                            running = false;
                            flush_deadline = Instant::now() + SHUTDOWN_FLUSH_BUDGET;
                        }
                        self.handle_cmd(cmd, running);
                    }
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        // Driver gone without a Shutdown (panic path):
                        // tear down as if one had arrived.
                        if running {
                            running = false;
                            flush_deadline = Instant::now() + SHUTDOWN_FLUSH_BUDGET;
                        }
                        break;
                    }
                }
            }
            if !running && (!self.flush_pending() || Instant::now() >= flush_deadline) {
                break;
            }
            fds.clear();
            tags.clear();
            fds.push(PollFd { fd: self.waker_rx.as_raw_fd(), events: POLLIN, revents: 0 });
            tags.push(FdTag::Waker);
            if running {
                fds.push(PollFd { fd: self.listener.as_raw_fd(), events: POLLIN, revents: 0 });
                tags.push(FdTag::Listener);
                for (p, link) in self.inbound.iter().enumerate() {
                    if let Some(l) = link {
                        fds.push(PollFd {
                            fd: l.stream.as_raw_fd(),
                            events: POLLIN,
                            revents: 0,
                        });
                        tags.push(FdTag::In(p));
                    }
                }
            }
            for (p, o) in self.out.iter().enumerate() {
                if let OutLink::Open { stream, pending, sent } = o {
                    if pending.len() > *sent {
                        fds.push(PollFd { fd: stream.as_raw_fd(), events: POLLOUT, revents: 0 });
                        tags.push(FdTag::Out(p));
                    }
                }
            }
            // The 500ms ceiling is a safety net: a lost wake could only
            // cost latency, never liveness. The drain phase polls fast
            // against its flush deadline.
            let timeout_ms = if running { 500 } else { 20 };
            let _ = poll_fds(&mut fds, timeout_ms);
            for (i, fd) in fds.iter().enumerate() {
                if fd.revents == 0 {
                    continue;
                }
                match tags[i] {
                    FdTag::Waker => self.drain_waker(),
                    FdTag::Listener => self.accept_ready(),
                    FdTag::In(p) => self.service_inbound(p),
                    FdTag::Out(p) => self.try_flush(p),
                }
            }
        }
        self.teardown();
    }

    fn handle_cmd(&mut self, cmd: IoCmd, running: bool) {
        match cmd {
            IoCmd::Send { to, step, fields } => {
                if running {
                    self.queue_frame(to, step, &fields, false);
                }
            }
            IoCmd::Broadcast { step, slot, digest, fields } => {
                if !running {
                    return;
                }
                let targets: Vec<PeerId> = match &mut self.relay {
                    Some(relay) => {
                        // Pre-mark our own digest: an echo of this
                        // broadcast arriving back over the overlay is a
                        // Duplicate, not a fresh variant to relay.
                        let _ = relay.tracker.observe_digest(self.me, step, slot, digest);
                        if step > relay.max_step {
                            relay.max_step = step;
                            relay.tracker.gc(step, RELAY_GC_HORIZON);
                        }
                        relay.schedule.overlay_at(step).out_neighbors(self.me).to_vec()
                    }
                    None => (0..self.info.n_peers)
                        .filter(|&to| {
                            to != self.me
                                && wire_admitted(
                                    &self.join_steps,
                                    &self.crash_steps,
                                    &self.rejoin_steps,
                                    to,
                                    step,
                                )
                        })
                        .collect(),
                };
                self.queue_broadcast(&targets, step, &fields);
            }
            IoCmd::Inbound { peer, stream, fr } => self.install_inbound(peer, stream, fr, running),
            IoCmd::DialDone { to, result } => self.dial_done(to, result),
            IoCmd::Shutdown => {} // the state flip happened in the caller
        }
    }

    fn install_inbound(&mut self, peer: PeerId, stream: TcpStream, fr: FrameReader, running: bool) {
        if running && self.inbound[peer].is_some() && self.rejoin_steps[peer].is_some() {
            // A rejoin-scheduled peer's restarted process may re-HELLO
            // before this loop noticed the first life's socket die.
            // The new link passed the full handshake, so it supersedes
            // the old one. (With signatures off this widens the
            // existing replay-DoS surface from burn-the-slot to
            // displace-the-slot — the module-docs caveat, same class.)
            if let Some(old) = self.inbound[peer].take() {
                let _ = old.stream.shutdown(Shutdown::Both);
                let mut g = self.gauge.lock();
                g.open_in = g.open_in.saturating_sub(1);
            }
        }
        if !running || self.inbound[peer].is_some() || stream.set_nonblocking(true).is_err() {
            if self.inbound[peer].is_some() {
                eprintln!(
                    "socket mesh (peer {}): dropping duplicate connection claiming peer {peer}",
                    self.me
                );
            }
            let _ = stream.shutdown(Shutdown::Both);
            return;
        }
        self.inbound[peer] = Some(InLink { stream, fr });
        {
            let mut g = self.gauge.lock();
            g.seen_in[peer] = true;
            g.open_in += 1;
        }
        self.gauge.cond.notify_all();
        // The sender may have pipelined envelopes right behind its HELLO
        // — they are already buffered inside `fr`; drain them now.
        self.service_inbound(peer);
    }

    fn dial_done(&mut self, to: PeerId, result: Result<TcpStream, String>) {
        let queued = match std::mem::replace(&mut self.out[to], OutLink::Dead) {
            OutLink::Dialing { queued } => queued,
            other => {
                // Not dialing — a completion raced something else
                // (should not happen); restore whatever was there.
                self.out[to] = other;
                return;
            }
        };
        match result {
            Ok(stream) => {
                let _ = stream.set_nodelay(true);
                if stream.set_nonblocking(true).is_err() {
                    let _ = stream.shutdown(Shutdown::Both);
                    return; // slot is already Dead
                }
                self.out[to] = OutLink::Open { stream, pending: queued, sent: 0 };
                self.gauge.lock().open_out += 1;
                self.try_flush(to);
            }
            Err(e) => {
                eprintln!("socket mesh (peer {}): late dial to peer {to} failed: {e}", self.me);
                // The slot stays Dead and the queued frames are dropped,
                // exactly like the old path's ignored write errors: the
                // protocol's timeout/ELIMINATE machinery owns a peer
                // that never comes up.
            }
        }
    }

    /// Queue one frame for `to`, dialing the link lazily on first use.
    /// The MAC counter advances even when the link is dead or the write
    /// later fails — a broken link never delivers later frames, so a
    /// gap there is unobservable.
    fn queue_frame(&mut self, to: PeerId, step: u64, fields: &[u8], is_relay: bool) {
        if to == self.me {
            return;
        }
        self.reset_rejoined_link(to, step);
        let prefix = match &mut self.mac_send[to] {
            Some(mac) => {
                let prefix = mac_frame_prefix(fields, mac.next_seq, &mac.key);
                mac.next_seq += 1;
                prefix
            }
            None => plain_frame_prefix(fields.len()),
        };
        self.queue_prefixed(to, prefix, fields, is_relay);
    }

    /// Queue one broadcast frame for every target. Observable per-link
    /// behavior is exactly a sequence of [`Self::queue_frame`] calls in
    /// target order — links are independent state machines, so hoisting
    /// every link's MAC-counter advance ahead of the queueing lets all
    /// the stream MACs run as one batched multi-buffer HMAC sweep.
    fn queue_broadcast(&mut self, targets: &[PeerId], step: u64, fields: &[u8]) {
        // Phase 1: per-link rejoin resets and MAC counter advances.
        let mut macs: Vec<(usize, [u8; 32], [u8; 8])> = Vec::new();
        for (ti, &to) in targets.iter().enumerate() {
            if to == self.me {
                continue;
            }
            self.reset_rejoined_link(to, step);
            if let Some(mac) = &mut self.mac_send[to] {
                macs.push((ti, mac.key, mac.next_seq.to_le_bytes()));
                mac.next_seq += 1;
            }
        }
        // Phase 2: every link's frame MAC in one batched sweep (the
        // fields bytes are shared; only key and counter differ).
        let parts: Vec<[&[u8]; 3]> = macs
            .iter()
            .map(|(_, _, seq)| [b"btard-mac-frame".as_slice(), seq, fields])
            .collect();
        let items: Vec<(&[u8], &[&[u8]])> = macs
            .iter()
            .zip(&parts)
            .map(|((_, key, _), p)| (key.as_slice(), p.as_slice()))
            .collect();
        let digests = hmac_sha256_batch(&items);
        let mut mac_prefix: Vec<Option<Vec<u8>>> = vec![None; targets.len()];
        for ((ti, _, seq), d) in macs.iter().zip(&digests) {
            mac_prefix[*ti] = Some(mac_frame_prefix_with(fields.len(), seq, d));
        }
        // Phase 3: queue per target in the original order.
        for (ti, &to) in targets.iter().enumerate() {
            if to == self.me {
                continue;
            }
            let prefix = match mac_prefix[ti].take() {
                Some(p) => p,
                None => plain_frame_prefix(fields.len()),
            };
            self.queue_prefixed(to, prefix, fields, false);
        }
    }

    /// Rejoin revival for a dead link: the link died with the peer's
    /// first life; its scheduled rejoin is a fresh process (fresh
    /// address, fresh reader), so the link state machine gets a fresh
    /// start — and the new stream's MAC counter restarts from zero.
    fn reset_rejoined_link(&mut self, to: PeerId, step: u64) {
        if matches!(self.out[to], OutLink::Dead)
            && self.rejoin_steps[to].map_or(false, |r| step >= r)
        {
            self.out[to] = OutLink::Absent;
            if let Some(mac) = &mut self.mac_send[to] {
                mac.next_seq = 0;
            }
        }
    }

    /// Tail of the frame-queueing path: dial bookkeeping, backlog
    /// enforcement and stats, shared by the single-frame and broadcast
    /// entry points.
    fn queue_prefixed(&mut self, to: PeerId, prefix: Vec<u8>, fields: &[u8], is_relay: bool) {
        let frame_len = prefix.len() + fields.len();
        if matches!(self.out[to], OutLink::Absent) {
            // First frame to this peer: start the HELLO-prefixed dial.
            let mut queued = Vec::with_capacity(self.hellos[to].len() + frame_len);
            queued.extend_from_slice(&self.hellos[to]);
            self.out[to] = OutLink::Dialing { queued };
            self.spawn_dial(to);
        }
        let flush = match &mut self.out[to] {
            // Dropped, like an ignored write error on the old path. The
            // frame never reaches a wire, so the wire plane skips it.
            OutLink::Dead | OutLink::Absent => return,
            OutLink::Dialing { queued } => {
                queued.extend_from_slice(&prefix);
                queued.extend_from_slice(fields);
                false
            }
            OutLink::Open { pending, .. } => {
                pending.extend_from_slice(&prefix);
                pending.extend_from_slice(fields);
                true
            }
        };
        if flush {
            self.try_flush(to);
        }
        self.enforce_backlog(to);
        if is_relay {
            self.info.stats.record_relay(self.me, frame_len);
        } else {
            self.info.stats.record_wire(self.me, frame_len);
        }
    }

    /// Kill a link whose unflushed backlog exceeded the cap: a slow or
    /// dead peer must cost its own link, never its neighbours' memory
    /// (a crashed peer's neighbours would otherwise buffer without
    /// bound until its rejoin). The protocol's timeout/ELIMINATE
    /// machinery — or the peer's scheduled rejoin revival — owns the
    /// link from here.
    fn enforce_backlog(&mut self, to: PeerId) {
        let backlog = match &self.out[to] {
            OutLink::Dialing { queued } => queued.len(),
            OutLink::Open { pending, sent, .. } => pending.len() - sent,
            _ => return,
        };
        if backlog <= self.max_link_backlog {
            return;
        }
        let was_open = matches!(self.out[to], OutLink::Open { .. });
        if let OutLink::Open { stream, .. } = &self.out[to] {
            let _ = stream.shutdown(Shutdown::Both);
        }
        eprintln!(
            "socket mesh (peer {}): outbound link to peer {to} exceeded the {}-byte backlog \
             cap ({backlog} bytes unflushed) — marking the link dead",
            self.me, self.max_link_backlog
        );
        self.out[to] = OutLink::Dead;
        if was_open {
            let mut g = self.gauge.lock();
            g.open_out = g.open_out.saturating_sub(1);
        }
    }

    /// One connect attempt on a short-lived thread: a healthy target
    /// accepts instantly (its listener has been up since process start)
    /// and a dead one must fail fast without stalling the loop — see
    /// `LATE_DIAL_BUDGET`. A rejoin-scheduled peer is the exception:
    /// its restarted process publishes a fresh address out of band and
    /// may still be starting, so those dials poll the address file with
    /// retry under `REJOIN_DIAL_BUDGET`.
    fn spawn_dial(&mut self, to: PeerId) {
        let addr = self.addrs[to].clone();
        let rejoin_addr = if self.rejoin_steps[to].is_some() {
            self.rejoin_addr_dir.as_ref().map(|d| d.join(format!("addr_{to}.rejoin")))
        } else {
            None
        };
        let cmd_tx = self.cmd_tx.clone();
        let waker = self.waker.clone();
        let name = format!("sock-dial-{}-to-{to}", self.me);
        let spawned = thread::Builder::new().name(name).spawn(move || {
            let result = match &rejoin_addr {
                None => dial_once(&addr, LATE_DIAL_BUDGET).map_err(|e| e.to_string()),
                Some(path) => {
                    let deadline = Instant::now() + REJOIN_DIAL_BUDGET;
                    loop {
                        // Prefer the republished address once it exists
                        // (the roster row's port belongs to the dead
                        // first life); fall back to the roster address
                        // while the restart is still in flight.
                        let fresh = std::fs::read_to_string(path)
                            .ok()
                            .map(|s| s.trim().to_string())
                            .filter(|s| !s.is_empty());
                        let target = fresh.as_deref().unwrap_or(&addr);
                        match dial_once(target, LATE_DIAL_BUDGET) {
                            Ok(s) => break Ok(s),
                            Err(e) => {
                                if Instant::now() >= deadline {
                                    break Err(e.to_string());
                                }
                                thread::sleep(Duration::from_millis(30));
                            }
                        }
                    }
                }
            };
            if cmd_tx.send(IoCmd::DialDone { to, result }).is_ok() {
                waker.wake();
            }
        });
        if let Err(e) = spawned {
            eprintln!("socket mesh (peer {}): spawning dial thread: {e}", self.me);
            self.out[to] = OutLink::Dead;
        }
    }

    fn try_flush(&mut self, to: PeerId) {
        let mut dead = false;
        if let OutLink::Open { stream, pending, sent } = &mut self.out[to] {
            while *sent < pending.len() {
                match stream.write(&pending[*sent..]) {
                    Ok(0) => {
                        dead = true;
                        break;
                    }
                    Ok(k) => *sent += k,
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == ErrorKind::Interrupted => {}
                    Err(_) => {
                        // The remote was banned or finished early —
                        // exactly like the perfect fabric's ignored
                        // channel-send errors.
                        dead = true;
                        break;
                    }
                }
            }
            if *sent == pending.len() {
                pending.clear();
                *sent = 0;
            }
        }
        if dead {
            self.out[to] = OutLink::Dead;
            let mut g = self.gauge.lock();
            g.open_out = g.open_out.saturating_sub(1);
        }
    }

    /// Read and decode everything a link has ready. The link is taken
    /// out of its slot while frames are handled (relaying borrows the
    /// rest of `self`) and put back unless it died.
    fn service_inbound(&mut self, peer: PeerId) {
        let Some(mut link) = self.inbound[peer].take() else { return };
        let mut alive = true;
        let mut buf = [0u8; 65536];
        'link: loop {
            // Drain every complete frame already buffered before
            // touching the socket again.
            loop {
                match link.fr.next_frame() {
                    Ok(Some(frame)) => {
                        if !self.handle_frame(peer, frame) {
                            // Hostile or corrupt link: close it. The
                            // protocol sees the peer as silent and
                            // ELIMINATEs it.
                            alive = false;
                            break 'link;
                        }
                    }
                    Ok(None) => break,
                    Err(_) => {
                        // Malformed frame: connection-fatal, per the
                        // codec contract.
                        alive = false;
                        break 'link;
                    }
                }
            }
            match link.stream.read(&mut buf) {
                Ok(0) => {
                    alive = false; // EOF: peer exited (banned / finished)
                    break;
                }
                Ok(k) => link.fr.feed(&buf[..k]),
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(_) => {
                    alive = false;
                    break;
                }
            }
        }
        if alive {
            self.inbound[peer] = Some(link);
        } else {
            let _ = link.stream.shutdown(Shutdown::Both);
            let mut g = self.gauge.lock();
            g.open_in = g.open_in.saturating_sub(1);
        }
    }

    /// Returns false when the frame condemns its link.
    fn handle_frame(&mut self, link_peer: PeerId, frame: Frame) -> bool {
        match frame {
            // Gossip mode admits *broadcast* envelopes from any
            // authenticated link: the frame may be a relay of another
            // origin's broadcast. The link MAC (session-MAC mode)
            // authenticates the relayer; the envelope's Schnorr
            // signature authenticates the *origin* — a forged relay is
            // dropped by the Inbox's signature gate at delivery,
            // attributed to nobody.
            Frame::Envelope(env) if self.relay.is_some() && env.broadcast => {
                self.handle_relayed(env)
            }
            // Point-to-point frames (and every frame on a full-mesh
            // link) must come from the link's authenticated peer.
            frame => match admit_frame(frame, link_peer) {
                Some(env) => {
                    let _ = self.mailbox.send(env);
                    true
                }
                None => false,
            },
        }
    }

    /// Relay-once dissemination: the first copy of each distinct
    /// (origin, step, slot, payload digest) is delivered locally and
    /// forwarded to our overlay out-neighbours; later copies are
    /// dropped. A *contradictory* variant (same key, different digest —
    /// an equivocation attempt) is also delivered and forwarded, bounded
    /// by a small per-key cap, so every honest peer obtains the same
    /// ban evidence the full mesh would have handed it.
    fn handle_relayed(&mut self, env: Envelope) -> bool {
        if env.from >= self.info.n_peers {
            return false; // spoofed origin id: condemn the link
        }
        if env.from == self.me {
            // An echo of our own broadcast; loopback already delivered
            // it, and the origination pre-marked its digest.
            return true;
        }
        let (seen, targets) = {
            let relay = self.relay.as_mut().expect("handle_relayed is gossip-only");
            let seen = relay.tracker.observe(&env);
            if env.step > relay.max_step {
                relay.max_step = env.step;
                relay.tracker.gc(env.step, RELAY_GC_HORIZON);
            }
            let targets: Vec<PeerId> =
                relay.schedule.overlay_at(env.step).out_neighbors(self.me).to_vec();
            (seen, targets)
        };
        match seen {
            Seen::Duplicate => true,
            Seen::First | Seen::Contradiction(_) => {
                let fields = envelope_fields(&env);
                let origin = env.from;
                let step = env.step;
                let _ = self.mailbox.send(env);
                for to in targets {
                    // Deterministic exclusion: never relay back to the
                    // origin (it has the message by definition). The
                    // arrival link is *not* excluded — that would make
                    // the relay graph timing-dependent.
                    if to != origin {
                        self.queue_frame(to, step, &fields, true);
                    }
                }
                true
            }
        }
    }

    fn accept_ready(&mut self) {
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    // Post-build handshakes get the slice budget (the
                    // build's hard deadline is long gone).
                    let hard = Instant::now() + HELLO_SLICE;
                    spawn_handshake(self.hs_ctx.clone(), stream, hard);
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) => {
                    // accept(2) errors like ECONNABORTED / EMFILE are
                    // transient; a silently dead accept path would
                    // strand every future link with nothing in the logs.
                    eprintln!("socket mesh (peer {}): acceptor error (retrying): {e}", self.me);
                    return;
                }
            }
        }
    }

    fn drain_waker(&mut self) {
        let mut buf = [0u8; 256];
        loop {
            match (&self.waker_rx).read(&mut buf) {
                Ok(0) => return,
                Ok(_) => {}
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(_) => return, // WouldBlock: drained
            }
        }
    }

    fn flush_pending(&self) -> bool {
        self.out
            .iter()
            .any(|o| matches!(o, OutLink::Open { pending, sent, .. } if pending.len() > *sent))
    }

    fn teardown(self) {
        // Outbound links carry no inbound data, so closing them reaches
        // the remote as a clean FIN after everything we flushed — an
        // early-exiting (banned) peer can never RST away envelopes an
        // honest receiver has not yet drained (the unidirectional-link
        // rationale in the module docs, preserved by the event loop).
        for link in &self.out {
            if let OutLink::Open { stream, .. } = link {
                let _ = stream.shutdown(Shutdown::Both);
            }
        }
        // Any RST that closing the inbound halves provokes lands on the
        // remote's send-only socket, where there is nothing to lose.
        for link in self.inbound.iter().flatten() {
            let _ = link.stream.shutdown(Shutdown::Both);
        }
        // Handshake and dial threads are bounded by construction
        // (HELLO_SLICE / LATE_DIAL_BUDGET), self-terminate, and notice
        // the dropped command channel — nothing here is unjoinable.
    }
}

/// A real-socket transport endpoint: one send-direction TCP connection
/// per ordered peer pair in use, a single poll(2)-driven I/O thread
/// owning every link, and the shared [`Inbox`] delivery semantics. With
/// a dynamic-membership schedule (`SocketConfig::join_steps`), links
/// involving late joiners form lazily at the joiner's epoch boundary.
/// In gossip mode (`SocketConfig::gossip`) broadcasts ride the overlay,
/// so the endpoint keeps O(fanout·log n) broadcast links instead of
/// O(n).
pub struct SocketNet {
    id: PeerId,
    info: Arc<ClusterInfo>,
    /// Message-authentication policy for everything sent and received:
    /// [`NoAuth`] when signatures are off, [`SessionAuth`] on a
    /// session-MAC mesh (adjudication slots signed, bulk parts ride the
    /// stream MAC), [`SchnorrAuth`] otherwise (every envelope signed).
    auth: Arc<dyn MessageAuth>,
    /// Per-peer join step (all zeros for a static roster).
    join_steps: Vec<u64>,
    /// Per-peer scheduled crash / rejoin steps (see [`SocketConfig`]).
    crash_steps: Vec<Option<u64>>,
    rejoin_steps: Vec<Option<u64>>,
    /// Driver → event-loop command queue, paired with `waker`.
    cmd_tx: Sender<IoCmd>,
    waker: Arc<LoopWaker>,
    io_thread: Option<thread::JoinHandle<()>>,
    gauge: Arc<LinkGauge>,
    /// Self-delivery: loopback never crosses the network.
    loopback: Sender<Envelope>,
    inbox: Inbox,
    timeout: Duration,
    recv_mode: RecvMode,
}

impl SocketNet {
    /// Build this peer's endpoint of the mesh: a founding member dials
    /// the founding peers it will write to — every other founding
    /// member in full-mesh mode, just its epoch-0 overlay
    /// out-neighbours in gossip mode — announcing itself with a HELLO,
    /// then hands every socket to the event loop and waits until the
    /// loop has accepted and validated the inbound links expected now.
    /// Links involving scheduled late joiners (and gossip
    /// point-to-point links) form lazily instead: the loop keeps
    /// accepting epoch-stamped HELLOs mid-run and dials missing links
    /// on first send. `listener` must already be bound to
    /// `roster.peers[id].addr` (bind-before-publish is what the
    /// rendezvous flow guarantees).
    ///
    /// No HELLO replies are exchanged: a dialer that waited for one
    /// while its own acceptor was idle would deadlock the all-dial-first
    /// build order, and the reply authenticated nothing the envelope
    /// signatures don't already. A misrouted roster address surfaces as
    /// the far end rejecting the HELLO (or dropping every forged
    /// envelope), never as silent misdelivery.
    pub fn connect(
        listener: TcpListener,
        roster: &Roster,
        id: PeerId,
        secret: SecretKey,
        cfg: &SocketConfig,
    ) -> std::io::Result<SocketNet> {
        let n = roster.n();
        if id >= n {
            return Err(io_err(format!("peer id {id} outside the {n}-peer roster")));
        }
        if secret.public != roster.peers[id].pubkey {
            return Err(io_err(format!(
                "peer {id}: secret key does not match the roster's pubkey"
            )));
        }
        let join_steps = if cfg.join_steps.is_empty() {
            vec![0u64; n]
        } else if cfg.join_steps.len() == n {
            cfg.join_steps.clone()
        } else {
            return Err(io_err(format!(
                "join_steps has {} entries for a {n}-peer roster",
                cfg.join_steps.len()
            )));
        };
        let norm_opt = |v: &[Option<u64>], what: &str| -> std::io::Result<Vec<Option<u64>>> {
            if v.is_empty() {
                Ok(vec![None; n])
            } else if v.len() == n {
                Ok(v.to_vec())
            } else {
                Err(io_err(format!("{what} has {} entries for a {n}-peer roster", v.len())))
            }
        };
        let crash_steps = norm_opt(&cfg.crash_steps, "crash_steps")?;
        let rejoin_steps = norm_opt(&cfg.rejoin_steps, "rejoin_steps")?;
        // A restarted second life announces itself at its rejoin epoch:
        // that is the admission the schedule grants it, and the epoch
        // acceptors will verify its HELLOs against.
        let my_epoch = if cfg.restarted {
            rejoin_steps[id].ok_or_else(|| {
                io_err(format!("peer {id} marked restarted but has no scheduled rejoin step"))
            })?
        } else {
            join_steps[id]
        };
        if cfg.session_mac && !cfg.verify_signatures {
            return Err(io_err(
                "session-MAC mode requires signature verification: the signed HELLO is \
                 what makes the MAC negotiation downgrade-proof"
                    .to_string(),
            ));
        }
        // Gossip mode: derive the full per-epoch overlay schedule up
        // front. It is a pure function of (epoch table, seed, fanout) —
        // every peer computes the identical relay graph, which is what
        // keeps dissemination deterministic enough to digest-compare
        // against the full mesh.
        let relay = if cfg.gossip {
            if cfg.gossip_fanout == 0 {
                return Err(io_err("gossip mode needs gossip_fanout >= 1".to_string()));
            }
            let epochs: Vec<(u64, Vec<PeerId>)> = if cfg.overlay_epochs.is_empty() {
                vec![(0, (0..n).filter(|&j| join_steps[j] == 0).collect())]
            } else {
                cfg.overlay_epochs.clone()
            };
            if epochs.first().map(|(s, _)| *s) != Some(0) {
                return Err(io_err("overlay_epochs must start at step 0".to_string()));
            }
            if let Some(&bad) = epochs.iter().flat_map(|(_, m)| m.iter()).find(|&&p| p >= n) {
                return Err(io_err(format!(
                    "overlay_epochs names peer {bad}, outside the {n}-peer roster"
                )));
            }
            Some(RelayState {
                schedule: OverlaySchedule::derive(
                    &epochs,
                    cfg.overlay_seed,
                    cfg.gossip_fanout as usize,
                ),
                tracker: RelayTracker::new(),
                max_step: 0,
            })
        } else {
            None
        };
        let mont = Mont::new();
        let info = Arc::new(ClusterInfo {
            n_peers: n,
            public_keys: roster.peers.iter().map(|p| p.pubkey).collect(),
            stats: TrafficStats::new(n),
            verify_signatures: cfg.verify_signatures,
        });
        let (tx, rx) = channel();
        let deadline = Instant::now() + cfg.connect_timeout;
        // One HELLO per recipient: the nonce binds the link (sender,
        // epoch, receiver), so a frame for peer j is garbage to peer k.
        // The roster digest is hashed once and reused everywhere.
        let roster_digest = roster.digest();
        let sign_hello = cfg.verify_signatures;
        let hellos: Vec<Vec<u8>> = (0..n)
            .map(|j| {
                if j == id {
                    Vec::new()
                } else {
                    encode_hello(
                        id,
                        my_epoch,
                        j,
                        &roster_digest,
                        &secret,
                        &mont,
                        cfg.session_mac,
                        sign_hello,
                    )
                }
            })
            .collect();

        // Outbound links a founding member opens during the build: the
        // whole founding mesh in full-mesh mode, just our epoch-0
        // overlay out-neighbours in gossip mode (point-to-point links
        // dial lazily on first use). TCP completes the connect via the
        // listener's backlog whether or not the remote has reached its
        // accept path yet, so the all-dials-then-all-accepts order
        // cannot deadlock. Dials run synchronously here with retry (the
        // target may legitimately not have bound its listener yet); the
        // streams then go non-blocking and hand over to the event loop.
        let mut out: Vec<OutLink> = (0..n).map(|_| OutLink::Absent).collect();
        let mut open_out = 0usize;
        // A restarted second life builds no founding links: like a late
        // joiner, everything forms lazily at its rejoin boundary (and
        // the roster addresses of its founding-mesh era may be stale).
        if join_steps[id] == 0 && !cfg.restarted {
            let dial_targets: Vec<PeerId> = match &relay {
                Some(r) => r
                    .schedule
                    .overlay_at(0)
                    .out_neighbors(id)
                    .iter()
                    .copied()
                    .filter(|&j| join_steps[j] == 0)
                    .collect(),
                None => (0..n).filter(|&j| j != id && join_steps[j] == 0).collect(),
            };
            for j in dial_targets {
                let mut stream = dial_with_retry(&roster.peers[j].addr, deadline)?;
                let _ = stream.set_nodelay(true);
                stream.write_all(&hellos[j])?;
                stream.set_nonblocking(true)?;
                out[j] = OutLink::Open { stream, pending: Vec::new(), sent: 0 };
                open_out += 1;
            }
        }

        // Inbound links the build must wait for: the send-direction
        // connection of every founding peer that dials us now — all of
        // them in full-mesh mode, our epoch-0 overlay in-neighbours in
        // gossip mode. A late joiner waits for nobody (its links form
        // mid-run), and connections beyond the expected set (a joiner
        // starting early, a gossip peer's lazy p2p link) are installed
        // the same way, just never counted toward the build.
        let expected_now: Vec<PeerId> = if join_steps[id] == 0 && !cfg.restarted {
            match &relay {
                Some(r) => r
                    .schedule
                    .overlay_at(0)
                    .in_neighbors(id)
                    .into_iter()
                    .filter(|&j| join_steps[j] == 0)
                    .collect(),
                None => (0..n).filter(|&j| j != id && join_steps[j] == 0).collect(),
            }
        } else {
            Vec::new()
        };

        // Everything is in place: start the event loop, which owns the
        // listener, every link and (in gossip mode) the relay state
        // from here on. Handshakes still run on short-lived helper
        // threads so a silent or hostile connection stalls only itself
        // for its HELLO_SLICE — probes must not be able to serialize
        // away the accept budget.
        listener.set_nonblocking(true)?;
        let (waker_tx, waker_rx) = UnixStream::pair()?;
        waker_tx.set_nonblocking(true)?;
        waker_rx.set_nonblocking(true)?;
        let waker = Arc::new(LoopWaker { tx: waker_tx });
        let (cmd_tx, cmd_rx) = channel();
        let gauge = Arc::new(LinkGauge::new(n));
        gauge.lock().open_out = open_out;
        let hs_ctx = Arc::new(HandshakeCtx {
            me: id,
            roster: roster.clone(),
            roster_digest,
            join_steps: join_steps.clone(),
            rejoin_steps: rejoin_steps.clone(),
            verify_signatures: cfg.verify_signatures,
            session_mac: cfg.session_mac,
            secret: secret.clone(),
            max_frame: cfg.max_frame,
            cmd_tx: cmd_tx.clone(),
            waker: waker.clone(),
        });
        let mac_send: Vec<Option<MacSend>> = (0..n)
            .map(|j| {
                if !cfg.session_mac || j == id {
                    return None;
                }
                let shared = shared_secret(&mont, &secret, &roster.peers[j].pubkey);
                Some(MacSend {
                    key: link_mac_key(&shared, id, j, &roster_digest),
                    next_seq: 0,
                })
            })
            .collect();
        let io_loop = IoLoop {
            me: id,
            info: info.clone(),
            listener,
            hs_ctx,
            cmd_rx,
            cmd_tx: cmd_tx.clone(),
            waker: waker.clone(),
            waker_rx,
            mailbox: tx.clone(),
            addrs: roster.peers.iter().map(|p| p.addr.clone()).collect(),
            hellos,
            join_steps: join_steps.clone(),
            crash_steps: crash_steps.clone(),
            rejoin_steps: rejoin_steps.clone(),
            rejoin_addr_dir: cfg.rejoin_addr_dir.clone(),
            max_link_backlog: cfg.max_link_backlog,
            mac_send,
            out,
            inbound: (0..n).map(|_| None).collect(),
            relay,
            gauge: gauge.clone(),
        };
        let io_thread = thread::Builder::new()
            .name(format!("sock-io-{id}"))
            .spawn(move || io_loop.run())
            .map_err(|e| io_err(format!("spawning I/O event-loop thread: {e}")))?;

        // Block until the loop has installed every expected inbound
        // link (it notifies the gauge per install), or tear the
        // half-built endpoint down on timeout — the loop thread must
        // not outlive the error.
        let mut state = gauge.lock();
        loop {
            let missing =
                expected_now.iter().filter(|&&j| !state.seen_in[j]).count();
            if missing == 0 {
                break;
            }
            let remaining = deadline.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                drop(state);
                let _ = cmd_tx.send(IoCmd::Shutdown);
                waker.wake();
                let _ = io_thread.join();
                return Err(timeout_err(&format!(
                    "waiting for {missing} inbound connection(s)"
                )));
            }
            let (next, _) = gauge
                .cond
                .wait_timeout(state, remaining.min(Duration::from_millis(100)))
                .unwrap_or_else(|p| p.into_inner());
            state = next;
        }
        drop(state);

        let auth: Arc<dyn MessageAuth> = if !cfg.verify_signatures {
            Arc::new(NoAuth)
        } else if cfg.session_mac {
            Arc::new(SessionAuth::new(
                mont.clone(),
                Some(secret.clone()),
                info.public_keys.clone(),
            ))
        } else {
            Arc::new(SchnorrAuth::new(
                mont.clone(),
                Some(secret.clone()),
                info.public_keys.clone(),
            ))
        };
        Ok(SocketNet {
            id,
            info,
            auth,
            join_steps,
            crash_steps,
            rejoin_steps,
            cmd_tx,
            waker,
            io_thread: Some(io_thread),
            gauge,
            loopback: tx,
            inbox: Inbox::new(rx),
            timeout: Duration::from_secs(30),
            recv_mode: RecvMode::Blocking,
        })
    }

    /// Currently open (inbound, outbound) link counts — what the net
    /// bench asserts stays O(fanout), not O(n), per peer in gossip
    /// mode.
    pub fn open_links(&self) -> (usize, usize) {
        let g = self.gauge.lock();
        (g.open_in, g.open_out)
    }

    fn make_envelope(
        &self,
        step: u64,
        slot: u32,
        class: MsgClass,
        payload: Vec<u8>,
        broadcast: bool,
    ) -> Envelope {
        let mut env = Envelope {
            from: self.id,
            step,
            slot,
            class,
            payload: payload.into(),
            broadcast,
            deliver_at: 0,
            signature: None,
        };
        self.auth.seal(&mut env);
        env
    }
}

impl Drop for SocketNet {
    fn drop(&mut self) {
        // One command tears the whole endpoint down: the loop stops
        // accepting and reading, flushes queued outbound bytes inside a
        // bounded budget, FINs the outbound links and closes the
        // inbound ones (see `IoLoop::teardown` for why that ordering
        // can never RST away an honest peer's undrained envelopes).
        let _ = self.cmd_tx.send(IoCmd::Shutdown);
        self.waker.wake();
        if let Some(handle) = self.io_thread.take() {
            let _ = handle.join();
        }
    }
}

impl Transport for SocketNet {
    fn id(&self) -> PeerId {
        self.id
    }

    fn info(&self) -> &Arc<ClusterInfo> {
        &self.info
    }

    fn set_timeout(&mut self, timeout: Duration) {
        self.timeout = timeout;
    }

    fn set_recv_mode(&mut self, mode: RecvMode) {
        self.recv_mode = mode;
    }

    fn tick(&mut self) {
        self.inbox.advance_clock(self.recv_mode);
    }

    fn clock(&self) -> u64 {
        self.inbox.now()
    }

    fn set_min_step(&mut self, step: u64) {
        self.inbox.set_min_step(step);
    }

    fn send(&mut self, to: PeerId, step: u64, slot: u32, class: MsgClass, payload: Vec<u8>) {
        let bytes = payload.len();
        let env = self.make_envelope(step, slot, class, payload, false);
        self.info.stats.record_p2p(self.id, class, bytes);
        if to == self.id {
            let _ = self.loopback.send(env);
        } else if wire_admitted(&self.join_steps, &self.crash_steps, &self.rejoin_steps, to, step) {
            // A not-yet-admitted joiner (or a peer inside its scheduled
            // crash window) gets nothing on the wire; the in-process
            // fabrics deliver-and-discard instead, which is observably
            // identical (the peer drops the traffic at snapshot
            // install).
            let fields = envelope_fields(&env);
            if self.cmd_tx.send(IoCmd::Send { to, step, fields }).is_ok() {
                self.waker.wake();
            }
        }
    }

    fn broadcast(&mut self, step: u64, slot: u32, class: MsgClass, payload: Vec<u8>) {
        let bytes = payload.len();
        let env = self.make_envelope(step, slot, class, payload, true);
        self.info.stats.record_broadcast(self.id, class, bytes);
        // The O(d) fields buffer is encoded once; the loop adds only
        // the small per-link prefix (plain, or `seq ‖ mac` on a MAC
        // link). The payload digest rides along so gossip mode can
        // pre-mark its relay tracker against echoes.
        let fields = envelope_fields(&env);
        let digest = sha256(&env.payload);
        let _ = self.loopback.send(env);
        if self.cmd_tx.send(IoCmd::Broadcast { step, slot, digest, fields }).is_ok() {
            self.waker.wake();
        }
    }

    fn broadcast_split(
        &mut self,
        step: u64,
        slot: u32,
        class: MsgClass,
        variants: Vec<(PeerId, Vec<u8>)>,
    ) {
        // Same distinct-variant relay semantics as every other backend:
        // each variant eventually reaches every peer.
        for payload in distinct_variants(&variants) {
            self.broadcast(step, slot, class, payload);
        }
    }

    fn recv_keyed(
        &mut self,
        step: u64,
        slot: u32,
        pred: &dyn Fn(&Envelope) -> bool,
    ) -> Result<Envelope, RecvError> {
        self.inbox.recv_keyed(
            self.auth.as_ref(),
            self.recv_mode,
            self.timeout,
            step,
            slot,
            pred,
        )
    }

    fn drain_match(&mut self, pred: &dyn Fn(&Envelope) -> bool) -> Vec<Envelope> {
        self.inbox.drain_match(self.auth.as_ref(), self.recv_mode, pred)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::slots;

    fn sample_envelope(signed: bool) -> Envelope {
        let mont = Mont::new();
        let sk = keygen(&mont, 42);
        let mut env = Envelope {
            from: 3,
            step: 17,
            slot: slots::sub(slots::GRAD_PART, 5),
            class: MsgClass::GradientPart,
            payload: vec![1, 2, 3, 4, 5].into(),
            broadcast: false,
            deliver_at: 0,
            signature: None,
        };
        if signed {
            env.sign_with(&mont, &sk);
        }
        env
    }

    fn assert_envelope_eq(a: &Envelope, b: &Envelope) {
        assert_eq!(a.from, b.from);
        assert_eq!(a.step, b.step);
        assert_eq!(a.slot, b.slot);
        assert_eq!(a.class, b.class);
        assert_eq!(a.broadcast, b.broadcast);
        assert_eq!(a.payload.to_vec(), b.payload.to_vec());
        assert_eq!(a.signature, b.signature);
        assert_eq!(b.deliver_at, 0, "wire envelopes are stamped for immediate delivery");
    }

    #[test]
    fn envelope_frame_roundtrip_signed_and_unsigned() {
        for signed in [false, true] {
            let env = sample_envelope(signed);
            let mut fr = FrameReader::new(DEFAULT_MAX_FRAME);
            fr.feed(&encode_envelope(&env));
            match fr.next_frame().unwrap() {
                Some(Frame::Envelope(got)) => assert_envelope_eq(&env, &got),
                other => panic!("expected envelope, got {other:?}"),
            }
            assert!(fr.next_frame().unwrap().is_none());
        }
    }

    #[test]
    fn mac_frames_roundtrip_and_reject_tamper_replay_and_plain() {
        let key = [7u8; 32];
        let a = sample_envelope(false);
        let b = sample_envelope(true);
        // In-order MAC frames decode; the counter advances per frame.
        let mut fr = FrameReader::new(DEFAULT_MAX_FRAME);
        fr.enable_mac(key);
        fr.feed(&encode_mac_envelope(&a, 0, &key));
        fr.feed(&encode_mac_envelope(&b, 1, &key));
        for want in [&a, &b] {
            match fr.next_frame().unwrap() {
                Some(Frame::Envelope(got)) => assert_envelope_eq(want, &got),
                other => panic!("expected envelope, got {other:?}"),
            }
        }
        // A replayed frame (stale counter) is rejected…
        let mut fr = FrameReader::new(DEFAULT_MAX_FRAME);
        fr.enable_mac(key);
        fr.feed(&encode_mac_envelope(&a, 0, &key));
        assert!(matches!(fr.next_frame(), Ok(Some(_))));
        fr.feed(&encode_mac_envelope(&a, 0, &key));
        assert_eq!(fr.next_frame().unwrap_err(), FrameError::BadSeq { got: 0, want: 1 });
        // …and so is a payload flip (the MAC no longer verifies).
        let mut fr = FrameReader::new(DEFAULT_MAX_FRAME);
        fr.enable_mac(key);
        let mut frame = encode_mac_envelope(&a, 0, &key);
        let last = frame.len() - 1;
        frame[last] ^= 1;
        fr.feed(&frame);
        assert_eq!(fr.next_frame().unwrap_err(), FrameError::BadMac);
        // A MAC frame under the wrong link key fails the same way.
        let mut fr = FrameReader::new(DEFAULT_MAX_FRAME);
        fr.enable_mac([8u8; 32]);
        fr.feed(&encode_mac_envelope(&a, 0, &key));
        assert_eq!(fr.next_frame().unwrap_err(), FrameError::BadMac);
        // A plain envelope frame on a MAC link can only be injected
        // bytes — the sender's endpoint always MACs.
        let mut fr = FrameReader::new(DEFAULT_MAX_FRAME);
        fr.enable_mac(key);
        fr.feed(&encode_envelope(&a));
        assert_eq!(fr.next_frame().unwrap_err(), FrameError::MacMissing);
        // And a MAC frame on a plain link has no key to check against.
        let mut fr = FrameReader::new(DEFAULT_MAX_FRAME);
        fr.feed(&encode_mac_envelope(&a, 0, &key));
        assert_eq!(fr.next_frame().unwrap_err(), FrameError::MacUnexpected);
    }

    #[test]
    fn link_mac_keys_are_directional_and_roster_bound() {
        let shared = [3u8; 32];
        let digest = [5u8; 32];
        let k01 = link_mac_key(&shared, 0, 1, &digest);
        assert_ne!(k01, link_mac_key(&shared, 1, 0, &digest), "directions share no key");
        assert_ne!(k01, link_mac_key(&shared, 0, 1, &[6u8; 32]), "rosters share no key");
        assert_eq!(k01, link_mac_key(&shared, 0, 1, &digest));
    }

    /// A small roster whose keys come from `derive_keypair(seed, k)`.
    fn test_roster(seed: u64, n: usize) -> Roster {
        let mont = Mont::new();
        Roster {
            peers: (0..n)
                .map(|k| RosterEntry {
                    id: k,
                    addr: format!("127.0.0.1:{}", 9000 + k),
                    pubkey: derive_keypair(&mont, seed, k).public,
                })
                .collect(),
        }
    }

    #[test]
    fn hello_frame_roundtrip_signed_and_unsigned() {
        let mont = Mont::new();
        let roster = test_roster(7, 14);
        let sk = derive_keypair(&mont, 7, 12);
        for signed in [false, true] {
            for mac in [false, true] {
                let mut fr = FrameReader::new(DEFAULT_MAX_FRAME);
                fr.feed(&encode_hello(12, 3, 5, &roster.digest(), &sk, &mont, mac, signed));
                match fr.next_frame().unwrap() {
                    Some(Frame::Hello(h)) => {
                        assert_eq!(h.id, 12);
                        assert_eq!(h.epoch, 3);
                        assert_eq!(h.nonce, roster.hello_nonce(12, 3, 5));
                        assert_eq!(h.pubkey, sk.public);
                        assert_eq!(h.mac, mac);
                        assert_eq!(h.signature.is_some(), signed);
                        if let Some(sig) = &h.signature {
                            // The signature binds the claimed (id, epoch,
                            // nonce, mac flag) to the roster key — the
                            // anti-spoof, anti-replay and anti-downgrade
                            // check of accept_handshake.
                            let msg = hello_signing_bytes(12, 3, &h.nonce, mac);
                            assert!(verify(&mont, &sk.public, &msg, sig));
                            let other_id = hello_signing_bytes(13, 3, &h.nonce, mac);
                            assert!(!verify(&mont, &sk.public, &other_id, sig));
                            let other_epoch = hello_signing_bytes(12, 4, &h.nonce, mac);
                            assert!(!verify(&mont, &sk.public, &other_epoch, sig));
                            let other_mac = hello_signing_bytes(12, 3, &h.nonce, !mac);
                            assert!(!verify(&mont, &sk.public, &other_mac, sig));
                        }
                    }
                    other => panic!("expected hello, got {other:?}"),
                }
            }
        }
    }

    #[test]
    fn hello_nonce_is_roster_epoch_and_link_bound() {
        let a = test_roster(7, 4);
        let mut b = test_roster(7, 4);
        b.peers[2].addr = "10.0.0.9:4444".to_string();
        // Same (id, epoch, receiver), different roster document ⇒
        // different nonce: a HELLO captured against one roster replays
        // as garbage against any other.
        assert_ne!(a.hello_nonce(1, 0, 0), b.hello_nonce(1, 0, 0));
        assert_ne!(a.hello_nonce(1, 0, 0), a.hello_nonce(1, 1, 0));
        assert_ne!(a.hello_nonce(1, 0, 0), a.hello_nonce(2, 0, 0));
        // Different receiver ⇒ different nonce: a capture of the 1→0
        // link cannot claim peer 1's inbound slot at peer 2.
        assert_ne!(a.hello_nonce(1, 0, 0), a.hello_nonce(1, 0, 2));
        assert_eq!(a.hello_nonce(1, 0, 0), test_roster(7, 4).hello_nonce(1, 0, 0));
    }

    #[test]
    fn reader_reassembles_byte_at_a_time_and_back_to_back_frames() {
        let a = sample_envelope(true);
        let b = sample_envelope(false);
        let mut bytes = encode_envelope(&a);
        bytes.extend_from_slice(&encode_envelope(&b));
        let mut fr = FrameReader::new(DEFAULT_MAX_FRAME);
        let mut got = Vec::new();
        for byte in &bytes {
            fr.feed(std::slice::from_ref(byte));
            while let Some(frame) = fr.next_frame().unwrap() {
                got.push(frame);
            }
        }
        assert_eq!(got.len(), 2);
        match (&got[0], &got[1]) {
            (Frame::Envelope(x), Frame::Envelope(y)) => {
                assert_envelope_eq(&a, x);
                assert_envelope_eq(&b, y);
            }
            other => panic!("expected two envelopes, got {other:?}"),
        }
    }

    #[test]
    fn oversized_frames_are_rejected_before_buffering() {
        let mut fr = FrameReader::new(1024);
        let mut header = Vec::new();
        header.extend_from_slice(&MAGIC);
        header.extend_from_slice(&(1_000_000u32).to_le_bytes());
        fr.feed(&header);
        assert_eq!(
            fr.next_frame().unwrap_err(),
            FrameError::Oversized { len: 1_000_000, max: 1024 }
        );
    }

    #[test]
    fn garbage_prefix_is_rejected() {
        let mut fr = FrameReader::new(1024);
        fr.feed(b"GET / HTTP/1.1\r\n");
        assert!(matches!(fr.next_frame(), Err(FrameError::BadMagic(_))));
    }

    #[test]
    fn malformed_bodies_are_rejected() {
        let frame_with_body = |body: &[u8]| {
            let mut out = Vec::new();
            out.extend_from_slice(&MAGIC);
            out.extend_from_slice(&(body.len() as u32).to_le_bytes());
            out.extend_from_slice(body);
            out
        };
        // Unknown kind.
        let mut fr = FrameReader::new(1024);
        fr.feed(&frame_with_body(&[9, 0, 0]));
        assert_eq!(fr.next_frame().unwrap_err(), FrameError::BadKind(9));
        // Envelope body shorter than its fixed fields.
        let mut fr = FrameReader::new(1024);
        fr.feed(&frame_with_body(&[KIND_ENVELOPE, 0, 0, 0]));
        assert!(matches!(fr.next_frame(), Err(FrameError::Truncated { .. })));
        // Bad message class.
        let env = sample_envelope(false);
        let mut bytes = encode_envelope(&env);
        bytes[8 + 21] = 99; // class byte
        let mut fr = FrameReader::new(1024);
        fr.feed(&bytes);
        assert_eq!(fr.next_frame().unwrap_err(), FrameError::BadClass(99));
        // Bad signature flag.
        let mut bytes = encode_envelope(&env);
        bytes[8 + 23] = 7; // sig flag
        let mut fr = FrameReader::new(1024);
        fr.feed(&bytes);
        assert_eq!(fr.next_frame().unwrap_err(), FrameError::BadFlag(7));
        // Signed flag set but signature bytes missing.
        let truncated = frame_with_body(&{
            let mut body = vec![KIND_ENVELOPE];
            body.extend_from_slice(&3u64.to_le_bytes());
            body.extend_from_slice(&0u64.to_le_bytes());
            body.extend_from_slice(&slots::GRAD_PART.to_le_bytes());
            body.push(MsgClass::GradientPart as u8);
            body.push(0);
            body.push(1); // signed, but no signature follows
            body
        });
        let mut fr = FrameReader::new(1024);
        fr.feed(&truncated);
        assert!(matches!(fr.next_frame(), Err(FrameError::Truncated { .. })));
    }

    #[test]
    fn admit_frame_enforces_link_identity() {
        let env = sample_envelope(false); // from = 3
        assert!(admit_frame(Frame::Envelope(env.clone()), 3).is_some());
        // Spoofed sender: the frame claims a peer other than the link's.
        assert!(admit_frame(Frame::Envelope(env), 2).is_none());
        // HELLO after the handshake is a protocol violation.
        let mont = Mont::new();
        let sk = keygen(&mont, 1);
        let hello = Hello {
            id: 3,
            epoch: 0,
            nonce: [0u8; 32],
            pubkey: sk.public,
            mac: false,
            signature: None,
        };
        assert!(admit_frame(Frame::Hello(hello), 3).is_none());
    }

    #[test]
    fn handshake_rejects_stale_epochs_and_foreign_nonces() {
        // Drive accept_handshake directly over a loopback socket pair.
        let roster = test_roster(21, 3);
        let mont = Mont::new();
        let sk1 = derive_keypair(&mont, 21, 1);
        let join_steps = vec![0u64, 0, 4]; // peer 2 is scheduled at epoch 4
        let rejoin_steps = vec![None, None, Some(6u64)]; // ...and rejoins at epoch 6 after a crash
        let run = |hello_bytes: Vec<u8>| -> Result<Hello, String> {
            let (listener, addr) = bind_ephemeral().unwrap();
            let writer = std::thread::spawn(move || {
                let mut s = TcpStream::connect(addr).unwrap();
                let _ = s.write_all(&hello_bytes);
                s // keep alive until the acceptor is done
            });
            let (mut stream, _) = listener.accept().unwrap();
            let mut fr = FrameReader::new(DEFAULT_MAX_FRAME);
            let res = accept_handshake(
                &mut stream,
                &mut fr,
                Instant::now() + Duration::from_secs(5),
                0,
                &roster,
                &roster.digest(),
                &join_steps,
                &rejoin_steps,
                &Mont::new(),
                true,
                false,
            );
            drop(writer.join().unwrap());
            res
        };
        // Correct epoch-0 HELLO from peer 1 to peer 0: accepted.
        let ok =
            run(encode_hello(1, 0, 0, &roster.digest(), &sk1, &mont, false, true)).unwrap();
        assert_eq!(ok.id, 1);
        // Stale epoch: peer 2 is scheduled at epoch 4, claims 0.
        let sk2 = derive_keypair(&mont, 21, 2);
        let err =
            run(encode_hello(2, 0, 0, &roster.digest(), &sk2, &mont, false, true)).unwrap_err();
        assert!(err.contains("stale HELLO"), "{err}");
        // Correct epoch for peer 2: accepted.
        let ok =
            run(encode_hello(2, 4, 0, &roster.digest(), &sk2, &mont, false, true)).unwrap();
        assert_eq!(ok.epoch, 4);
        // Post-crash rejoin epoch for peer 2: also accepted — a
        // restarted process re-HELLOs at its scheduled rejoin step.
        let ok =
            run(encode_hello(2, 6, 0, &roster.digest(), &sk2, &mont, false, true)).unwrap();
        assert_eq!(ok.epoch, 6);
        // But an epoch that is neither the schedule's nor the rejoin's
        // stays rejected.
        let err =
            run(encode_hello(2, 5, 0, &roster.digest(), &sk2, &mont, false, true)).unwrap_err();
        assert!(err.contains("stale HELLO"), "{err}");
        // A HELLO minted against a different roster document (same ids
        // and keys, different addr rows): the nonce no longer matches.
        let mut foreign = roster.clone();
        foreign.peers[0].addr = "10.1.2.3:9".to_string();
        let err =
            run(encode_hello(1, 0, 0, &foreign.digest(), &sk1, &mont, false, true)).unwrap_err();
        assert!(err.contains("nonce"), "{err}");
        // A genuine same-run HELLO captured from the 1→2 link and
        // replayed at peer 0: the link-bound nonce no longer matches,
        // so the replay cannot burn peer 1's inbound slot here.
        let err =
            run(encode_hello(1, 0, 2, &roster.digest(), &sk1, &mont, false, true)).unwrap_err();
        assert!(err.contains("nonce"), "{err}");
        // Unsigned HELLO while signatures are on: rejected.
        let err =
            run(encode_hello(1, 0, 0, &roster.digest(), &sk1, &mont, false, false)).unwrap_err();
        assert!(err.contains("unsigned"), "{err}");
        // Session-MAC mismatch: a signed HELLO honestly claiming MAC
        // mode is rejected by a plain-signature endpoint (and a forged
        // flag flip would already have failed the signature check).
        let err =
            run(encode_hello(1, 0, 0, &roster.digest(), &sk1, &mont, true, true)).unwrap_err();
        assert!(err.contains("session_mac"), "{err}");
    }

    #[test]
    fn late_joiner_links_form_after_the_founding_mesh() {
        // Universe {0, 1, 2}; peer 2 joins at step 3. The founding mesh
        // builds between 0 and 1 alone; peer 2's endpoint comes up with
        // zero links and everything forms lazily: incumbents dial it on
        // first send, it dials them on its first send, and the
        // epoch-stamped HELLOs pass the acceptors.
        let mont = Mont::new();
        let (l0, a0) = bind_ephemeral().unwrap();
        let (l1, a1) = bind_ephemeral().unwrap();
        let (l2, a2) = bind_ephemeral().unwrap();
        let roster = Roster {
            peers: vec![
                RosterEntry { id: 0, addr: a0, pubkey: derive_keypair(&mont, 31, 0).public },
                RosterEntry { id: 1, addr: a1, pubkey: derive_keypair(&mont, 31, 1).public },
                RosterEntry { id: 2, addr: a2, pubkey: derive_keypair(&mont, 31, 2).public },
            ],
        };
        let cfg = SocketConfig {
            connect_timeout: Duration::from_secs(20),
            join_steps: vec![0, 0, 3],
            ..Default::default()
        };
        let (rr, cc) = (roster.clone(), cfg.clone());
        let t1 = std::thread::spawn(move || {
            let mont = Mont::new();
            let mut net =
                SocketNet::connect(l1, &rr, 1, derive_keypair(&mont, 31, 1), &cc).unwrap();
            net.set_timeout(Duration::from_secs(20));
            // Wait for the joiner's step-3 broadcast, then answer it.
            let env = net.recv_keyed(3, slots::GRAD_COMMIT, &|e| e.from == 2).unwrap();
            assert_eq!(env.payload.to_vec(), vec![22]);
            net.send(2, 3, slots::GRAD_PART, MsgClass::GradientPart, vec![12]);
        });
        let (rr, cc) = (roster.clone(), cfg.clone());
        let t2 = std::thread::spawn(move || {
            let mont = Mont::new();
            // The joiner's connect returns immediately: no founding
            // links to build.
            let mut net =
                SocketNet::connect(l2, &rr, 2, derive_keypair(&mont, 31, 2), &cc).unwrap();
            net.set_timeout(Duration::from_secs(20));
            // First send at its boundary step lazily dials everyone.
            net.broadcast(3, slots::GRAD_COMMIT, MsgClass::Commitment, vec![22]);
            let env = net.recv_keyed(3, slots::GRAD_PART, &|e| e.from == 1).unwrap();
            assert_eq!(env.payload.to_vec(), vec![12]);
            let env = net.recv_keyed(3, slots::AGG_PART, &|e| e.from == 0).unwrap();
            assert_eq!(env.payload.to_vec(), vec![13]);
        });
        let mut net0 =
            SocketNet::connect(l0, &roster, 0, derive_keypair(&mont, 31, 0), &cfg).unwrap();
        net0.set_timeout(Duration::from_secs(20));
        // Pre-boundary sends to the joiner stay off the wire (gated).
        net0.send(2, 1, slots::GRAD_PART, MsgClass::GradientPart, vec![99]);
        // Incumbent 0 sees the joiner's broadcast, then dials it lazily.
        let env = net0.recv_keyed(3, slots::GRAD_COMMIT, &|e| e.from == 2).unwrap();
        assert_eq!(env.payload.to_vec(), vec![22]);
        net0.send(2, 3, slots::AGG_PART, MsgClass::AggregatedPart, vec![13]);
        t1.join().unwrap();
        t2.join().unwrap();
    }

    #[test]
    fn roster_roundtrip_and_validation() {
        let mont = Mont::new();
        let peers: Vec<RosterEntry> = (0..3)
            .map(|k| RosterEntry {
                id: k,
                addr: format!("127.0.0.1:{}", 9000 + k),
                pubkey: derive_keypair(&mont, 7, k).public,
            })
            .collect();
        let roster = Roster { peers };
        let parsed = Roster::parse(&roster.to_json()).unwrap();
        assert_eq!(parsed, roster);
        // Non-contiguous ids are rejected.
        let mut bad = roster.clone();
        bad.peers[2].id = 5;
        assert!(Roster::parse(&bad.to_json()).is_err());
        // Malformed pubkey hex is rejected.
        assert!(Roster::parse(
            r#"{"peers": [{"id": 0, "addr": "a:1", "pubkey": "zz"},
                           {"id": 1, "addr": "a:2", "pubkey": "00"}]}"#
        )
        .is_err());
        // A single peer is not a cluster.
        assert!(Roster::parse(r#"{"peers": [{"id": 0, "addr": "a:1", "pubkey": ""}]}"#).is_err());
    }

    #[test]
    fn derive_keypair_matches_in_process_builder() {
        // build_cluster(n, key_seed, …) derives peer k's key from
        // key_seed + k with key_seed = run_seed ^ 0xC1A5; the socket
        // path must agree or signatures (and digests) diverge.
        let mont = Mont::new();
        let run_seed = 7u64;
        let cluster = crate::net::build_cluster(3, run_seed ^ 0xC1A5, true);
        for (k, peer) in cluster.iter().enumerate() {
            assert_eq!(derive_keypair(&mont, run_seed, k).public, peer.info.public_keys[k]);
        }
    }

    #[test]
    fn two_peer_socket_mesh_exchanges_signed_envelopes() {
        let mont = Mont::new();
        let (l0, a0) = bind_ephemeral().unwrap();
        let (l1, a1) = bind_ephemeral().unwrap();
        let roster = Roster {
            peers: vec![
                RosterEntry { id: 0, addr: a0, pubkey: derive_keypair(&mont, 5, 0).public },
                RosterEntry { id: 1, addr: a1, pubkey: derive_keypair(&mont, 5, 1).public },
            ],
        };
        let cfg = SocketConfig { connect_timeout: Duration::from_secs(10), ..Default::default() };
        let r1 = roster.clone();
        let c1 = cfg.clone();
        let t1 = std::thread::spawn(move || {
            let mont = Mont::new();
            let mut net = SocketNet::connect(l1, &r1, 1, derive_keypair(&mont, 5, 1), &c1).unwrap();
            net.send(0, 2, slots::GRAD_PART, MsgClass::GradientPart, vec![42]);
            net.broadcast(2, slots::GRAD_COMMIT, MsgClass::Commitment, vec![7, 8]);
            // Wait for peer 0's reply before dropping the endpoint.
            let env = net.recv_keyed(2, slots::VERIFY_SCALARS, &|_| true).unwrap();
            assert_eq!(env.from, 0);
            assert_eq!(env.payload.to_vec(), vec![9]);
        });
        let mut net0 =
            SocketNet::connect(l0, &roster, 0, derive_keypair(&mont, 5, 0), &cfg).unwrap();
        net0.set_timeout(Duration::from_secs(10));
        let p2p = net0.recv_keyed(2, slots::GRAD_PART, &|e| e.from == 1).unwrap();
        assert_eq!(p2p.payload.to_vec(), vec![42]);
        assert!(p2p.signature.is_some(), "wire envelopes are signed when verification is on");
        let bc = net0.recv_keyed(2, slots::GRAD_COMMIT, &|e| e.from == 1).unwrap();
        assert_eq!(bc.payload.to_vec(), vec![7, 8]);
        assert!(bc.broadcast);
        net0.send(1, 2, slots::VERIFY_SCALARS, MsgClass::Verification, vec![9]);
        t1.join().unwrap();
        // Sender-side protocol-plane accounting matches the perfect
        // fabric's (payload bytes, charged once per logical message —
        // frame bytes and dissemination fan-out live on the wire plane).
        assert_eq!(net0.info().stats.total_bytes(0), 1);
        assert!(net0.info().stats.wire_bytes(0) > 0, "the reply frame hit a real wire");
    }

    #[test]
    fn session_mac_mesh_signs_adjudication_slots_only() {
        let mont = Mont::new();
        let (l0, a0) = bind_ephemeral().unwrap();
        let (l1, a1) = bind_ephemeral().unwrap();
        let roster = Roster {
            peers: vec![
                RosterEntry { id: 0, addr: a0, pubkey: derive_keypair(&mont, 11, 0).public },
                RosterEntry { id: 1, addr: a1, pubkey: derive_keypair(&mont, 11, 1).public },
            ],
        };
        let cfg = SocketConfig {
            session_mac: true,
            connect_timeout: Duration::from_secs(10),
            ..Default::default()
        };
        let r1 = roster.clone();
        let c1 = cfg.clone();
        let t1 = std::thread::spawn(move || {
            let mont = Mont::new();
            let mut net =
                SocketNet::connect(l1, &r1, 1, derive_keypair(&mont, 11, 1), &c1).unwrap();
            net.set_timeout(Duration::from_secs(10));
            net.send(0, 2, slots::GRAD_PART, MsgClass::GradientPart, vec![42]);
            net.broadcast(2, slots::GRAD_COMMIT, MsgClass::Commitment, vec![7, 8]);
            let env = net.recv_keyed(2, slots::VERIFY_SCALARS, &|_| true).unwrap();
            assert_eq!(env.payload.to_vec(), vec![9]);
        });
        let mut net0 =
            SocketNet::connect(l0, &roster, 0, derive_keypair(&mont, 11, 0), &cfg).unwrap();
        net0.set_timeout(Duration::from_secs(10));
        // Bulk parts ride the stream MAC: unsigned on the wire, still
        // delivered only if every frame on the link authenticates.
        let p2p = net0.recv_keyed(2, slots::GRAD_PART, &|e| e.from == 1).unwrap();
        assert_eq!(p2p.payload.to_vec(), vec![42]);
        assert!(p2p.signature.is_none(), "bulk parts ride the stream MAC unsigned");
        // Adjudication-bound slots keep their transferable signature,
        // and it verifies under the sender's roster key.
        let bc = net0.recv_keyed(2, slots::GRAD_COMMIT, &|e| e.from == 1).unwrap();
        assert_eq!(bc.payload.to_vec(), vec![7, 8]);
        assert!(bc.signature.is_some(), "commitments stay Schnorr-signed in MAC mode");
        assert!(bc.verify_with(&mont, &roster.peers[1].pubkey));
        net0.send(1, 2, slots::VERIFY_SCALARS, MsgClass::Verification, vec![9]);
        t1.join().unwrap();
    }

    #[test]
    fn session_mac_requires_signature_verification() {
        let mont = Mont::new();
        let (l0, _a0) = bind_ephemeral().unwrap();
        let roster = test_roster(3, 2);
        let cfg =
            SocketConfig { session_mac: true, verify_signatures: false, ..Default::default() };
        let err =
            SocketNet::connect(l0, &roster, 0, derive_keypair(&mont, 3, 0), &cfg).unwrap_err();
        assert!(err.to_string().contains("session-MAC"), "{err}");
    }

    #[test]
    fn gossip_requires_nonzero_fanout() {
        let mont = Mont::new();
        let (l0, _a0) = bind_ephemeral().unwrap();
        let roster = test_roster(3, 2);
        let cfg = SocketConfig { gossip: true, gossip_fanout: 0, ..Default::default() };
        let err =
            SocketNet::connect(l0, &roster, 0, derive_keypair(&mont, 3, 0), &cfg).unwrap_err();
        assert!(err.to_string().contains("gossip_fanout"), "{err}");
    }

    /// Fanout 1 degenerates the overlay to a single directed ring, so a
    /// broadcast reaches three of the four peers only by being relayed
    /// peer-to-peer-to-peer — the strongest possible exercise of the
    /// relay path (with fanout ≥ ⌈log₂ n⌉ some links are direct).
    #[test]
    fn gossip_ring_relays_broadcasts_to_everyone() {
        let mont = Mont::new();
        let n = 4;
        let seed = 13;
        let (listeners, addrs): (Vec<_>, Vec<_>) =
            (0..n).map(|_| bind_ephemeral().unwrap()).unzip();
        let roster = Roster {
            peers: addrs
                .into_iter()
                .enumerate()
                .map(|(k, addr)| RosterEntry {
                    id: k,
                    addr,
                    pubkey: derive_keypair(&mont, seed, k).public,
                })
                .collect(),
        };
        let cfg = SocketConfig {
            gossip: true,
            gossip_fanout: 1,
            overlay_seed: 99,
            connect_timeout: Duration::from_secs(10),
            ..Default::default()
        };
        let handles: Vec<_> = listeners
            .into_iter()
            .enumerate()
            .map(|(k, listener)| {
                let roster = roster.clone();
                let cfg = cfg.clone();
                std::thread::spawn(move || {
                    let mont = Mont::new();
                    let mut net = SocketNet::connect(
                        listener,
                        &roster,
                        k,
                        derive_keypair(&mont, seed, k),
                        &cfg,
                    )
                    .unwrap();
                    net.set_timeout(Duration::from_secs(10));
                    // A ring endpoint keeps exactly one link each way —
                    // the O(fanout) claim at its smallest.
                    assert_eq!(net.open_links(), (1, 1));
                    net.broadcast(2, slots::GRAD_COMMIT, MsgClass::Commitment, vec![k as u8; 3]);
                    // Every peer's broadcast arrives (self included via
                    // loopback), signed by its true origin.
                    for from in 0..n {
                        let env = net
                            .recv_keyed(2, slots::GRAD_COMMIT, &|e| e.from == from)
                            .unwrap_or_else(|e| panic!("peer {k} missing broadcast from {from}: {e:?}"));
                        assert_eq!(env.payload.to_vec(), vec![from as u8; 3]);
                        assert!(env.verify_with(&Mont::new(), &roster.peers[from].pubkey));
                    }
                    // Three relays each (everyone forwards everyone
                    // else's broadcast once, minus the origin exclusion).
                    let wire = net.info().stats.wire_snapshot();
                    assert!(wire[k].relay_msgs >= 2, "ring peers must relay: {:?}", wire[k]);
                    net
                })
            })
            .collect();
        // Keep every endpoint alive until all peers finished collecting,
        // then drop them together (mirrors the cluster harness).
        let nets: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        drop(nets);
    }
}
