//! Real-socket transport: the first `Transport` backend that leaves the
//! process.
//!
//! `SocketNet` implements the full transport contract over a loopback/LAN
//! TCP mesh so BTARD runs between *actual* OS processes — the setting the
//! paper (and DeDLOC-style open collaborations) assumes, where peers
//! share nothing but a roster and the wire. The pieces:
//!
//! - **Frame codec.** Length-prefixed signed-envelope frames
//!   (`encode_envelope` / `FrameReader`): a fixed `BTRD` magic, a u32
//!   body length, and a body carrying either a roster handshake HELLO or
//!   a protocol [`Envelope`]. The reader rejects oversized frames before
//!   allocating and treats any malformed byte (bad magic, unknown kind,
//!   bad class, truncated body) as a connection-fatal error — a hostile
//!   peer can kill its own link, never the receiver. `deliver_at` is
//!   transport routing metadata and is *not* serialized: a socket link is
//!   a perfect link, every received envelope is stamped 0.
//! - **Roster handshake.** Peers find each other through a JSON
//!   [`Roster`] (peer id, listen address, hex public key). Links are
//!   **unidirectional**: for every ordered pair (i → j) the *sender*
//!   dials the receiver's listener and opens a connection that only
//!   ever carries i's envelopes, prefixed by a HELLO frame (id, pubkey)
//!   the acceptor checks against the roster. One connection per
//!   direction is a deliberate correctness choice, not an accident: a
//!   peer that exits early (banned mid-run) closes sockets that may
//!   carry unread inbound data, and TCP answers further traffic on such
//!   a socket with RST — which on the *other* end discards any
//!   undelivered receive data on that same connection. With
//!   bidirectional links that could silently eat an honest peer's
//!   still-buffered envelopes; with send-only links every RST lands on
//!   a socket the victim never reads from, so nothing can be lost.
//!   When signature verification is on, the HELLO itself is signed with
//!   the sender's roster key (so an impostor cannot claim another
//!   peer's link), and a reader thread additionally drops any frame
//!   whose `from` does not match the link's authenticated peer. With
//!   verification off (`--no-sigs`, a benchmarking mode) nothing on the
//!   wire is authenticated — by construction, not oversight.
//! - **Shared delivery semantics.** Each link gets a reader thread that
//!   decodes frames into the same mpsc mailbox the in-process fabric
//!   uses, behind the same [`Inbox`]: signature gating, the canonical
//!   `(step, slot, from)` pending order, keyed binary-search collects and
//!   the logical phase clock all survive the wire unchanged. A socket
//!   peer therefore runs the *blocking* receive mode of the threaded
//!   execution model (there is no cross-process stage barrier to make
//!   drain mode's never-block contract sound), and the threaded path is
//!   bit-identical to the pooled one — which is how a multi-process
//!   cluster reproduces the in-process golden digest bit-for-bit
//!   (`harness::cluster`, `rust/tests/socket_transport.rs`).
//!
//! Simulation-grade caveats, deliberate and documented: per-peer keys are
//! derived deterministically from the run seed ([`derive_keypair`], the
//! same derivation the in-process builder uses — that is what makes the
//! signatures, and so the digests, comparable), and the signed HELLO is
//! replayable (a man-in-the-middle that captured one can occupy the
//! victim's inbound slot — a denial of service, never a forgery: every
//! envelope signature still fails against the roster key).

use std::io::{ErrorKind, Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use super::local::{distinct_variants, ClusterInfo, Inbox};
use super::{Envelope, MsgClass, PeerId, RecvError, RecvMode, TrafficStats, Transport};
use crate::crypto::{keygen, sign, verify, Mont, PublicKey, SecretKey, Signature};
use crate::util::json::Json;
use crate::util::{hex, unhex};

/// Frame magic: every frame starts with these four bytes.
pub const MAGIC: [u8; 4] = *b"BTRD";
/// Default cap on a frame body (64 MiB ≈ a 16M-parameter f32 gradient
/// part) — a hostile length prefix must not become an allocation bomb.
pub const DEFAULT_MAX_FRAME: usize = 64 << 20;

const KIND_HELLO: u8 = 1;
const KIND_ENVELOPE: u8 = 2;
/// kind + from + step + slot + class + broadcast + sig flag.
const ENVELOPE_FIXED: usize = 1 + 8 + 8 + 4 + 1 + 1 + 1;
/// kind + id + pubkey + sig flag (+ 64-byte signature when flagged).
const HELLO_FIXED: usize = 1 + 8 + 32 + 1;

/// Why a frame (and with it, the connection) was rejected.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FrameError {
    /// Stream prefix is not the `BTRD` magic — garbage or a stray
    /// protocol speaking on our port.
    BadMagic([u8; 4]),
    /// Declared body length exceeds the receiver's frame cap.
    Oversized { len: usize, max: usize },
    /// Unknown frame kind byte.
    BadKind(u8),
    /// Body shorter than its kind's fixed fields.
    Truncated { need: usize, have: usize },
    /// Byte that names no `MsgClass`.
    BadClass(u8),
    /// Broadcast / signature flag outside {0, 1}.
    BadFlag(u8),
    /// Sender id does not fit this platform's `usize`.
    BadPeer(u64),
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::BadMagic(m) => write!(f, "bad frame magic {m:02x?}"),
            FrameError::Oversized { len, max } => {
                write!(f, "frame body of {len} bytes exceeds the {max}-byte cap")
            }
            FrameError::BadKind(k) => write!(f, "unknown frame kind {k}"),
            FrameError::Truncated { need, have } => {
                write!(f, "truncated frame body: need {need} bytes, have {have}")
            }
            FrameError::BadClass(c) => write!(f, "byte {c} names no message class"),
            FrameError::BadFlag(b) => write!(f, "flag byte {b} outside {{0, 1}}"),
            FrameError::BadPeer(p) => write!(f, "peer id {p} does not fit usize"),
        }
    }
}

/// A decoded frame: the roster handshake or a protocol envelope.
#[derive(Debug)]
pub enum Frame {
    Hello(Hello),
    Envelope(Envelope),
}

/// Handshake payload: who is on the other end of this link. The
/// signature (present whenever the cluster verifies signatures) covers
/// the domain-tagged id, so only the holder of the roster key can claim
/// a peer's link.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Hello {
    pub id: PeerId,
    pub pubkey: PublicKey,
    pub signature: Option<Signature>,
}

/// The byte string a HELLO's signature covers.
fn hello_signing_bytes(id: PeerId) -> Vec<u8> {
    let mut msg = Vec::with_capacity(19);
    msg.extend_from_slice(b"btard-hello");
    msg.extend_from_slice(&(id as u64).to_le_bytes());
    msg
}

/// Encode a HELLO frame (header + body), signed with the sender's
/// roster key when `sign_hello` (i.e. the cluster verifies signatures).
pub fn encode_hello(id: PeerId, secret: &SecretKey, mont: &Mont, sign_hello: bool) -> Vec<u8> {
    let sig_len = if sign_hello { 64 } else { 0 };
    let body_len = HELLO_FIXED + sig_len;
    let mut out = Vec::with_capacity(8 + body_len);
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&(body_len as u32).to_le_bytes());
    out.push(KIND_HELLO);
    out.extend_from_slice(&(id as u64).to_le_bytes());
    out.extend_from_slice(&secret.public.0);
    if sign_hello {
        out.push(1);
        out.extend_from_slice(&sign(mont, secret, &hello_signing_bytes(id)).to_bytes());
    } else {
        out.push(0);
    }
    out
}

/// Encode an envelope frame (header + body). `deliver_at` is routing
/// metadata stamped by the *receiving* transport, never serialized.
pub fn encode_envelope(env: &Envelope) -> Vec<u8> {
    let sig_len = if env.signature.is_some() { 64 } else { 0 };
    let body_len = ENVELOPE_FIXED + sig_len + env.payload.len();
    assert!(body_len <= u32::MAX as usize, "envelope payload too large for the frame codec");
    let mut out = Vec::with_capacity(8 + body_len);
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&(body_len as u32).to_le_bytes());
    out.push(KIND_ENVELOPE);
    out.extend_from_slice(&(env.from as u64).to_le_bytes());
    out.extend_from_slice(&env.step.to_le_bytes());
    out.extend_from_slice(&env.slot.to_le_bytes());
    out.push(env.class as u8);
    out.push(env.broadcast as u8);
    match &env.signature {
        Some(sig) => {
            out.push(1);
            out.extend_from_slice(&sig.to_bytes());
        }
        None => out.push(0),
    }
    out.extend_from_slice(&env.payload);
    out
}

fn le_u64(b: &[u8]) -> u64 {
    u64::from_le_bytes(b[..8].try_into().unwrap())
}

fn decode_body(body: &[u8]) -> Result<Frame, FrameError> {
    let kind = *body.first().ok_or(FrameError::Truncated { need: 1, have: 0 })?;
    match kind {
        KIND_HELLO => {
            if body.len() < HELLO_FIXED {
                return Err(FrameError::Truncated { need: HELLO_FIXED, have: body.len() });
            }
            let id = le_u64(&body[1..9]);
            let id: PeerId = usize::try_from(id).map_err(|_| FrameError::BadPeer(id))?;
            let mut pk = [0u8; 32];
            pk.copy_from_slice(&body[9..41]);
            let signature = match body[41] {
                0 if body.len() == HELLO_FIXED => None,
                1 if body.len() == HELLO_FIXED + 64 => {
                    Signature::from_bytes(&body[HELLO_FIXED..HELLO_FIXED + 64])
                }
                0 | 1 => {
                    return Err(FrameError::Truncated {
                        need: HELLO_FIXED + 64 * body[41] as usize,
                        have: body.len(),
                    })
                }
                b => return Err(FrameError::BadFlag(b)),
            };
            Ok(Frame::Hello(Hello { id, pubkey: PublicKey(pk), signature }))
        }
        KIND_ENVELOPE => {
            if body.len() < ENVELOPE_FIXED {
                return Err(FrameError::Truncated { need: ENVELOPE_FIXED, have: body.len() });
            }
            let from = le_u64(&body[1..9]);
            let from: PeerId = usize::try_from(from).map_err(|_| FrameError::BadPeer(from))?;
            let step = le_u64(&body[9..17]);
            let slot = u32::from_le_bytes(body[17..21].try_into().unwrap());
            let class = MsgClass::from_u8(body[21]).ok_or(FrameError::BadClass(body[21]))?;
            let broadcast = match body[22] {
                0 => false,
                1 => true,
                b => return Err(FrameError::BadFlag(b)),
            };
            let (signature, payload_at) = match body[23] {
                0 => (None, ENVELOPE_FIXED),
                1 => {
                    let end = ENVELOPE_FIXED + 64;
                    if body.len() < end {
                        return Err(FrameError::Truncated { need: end, have: body.len() });
                    }
                    (Signature::from_bytes(&body[ENVELOPE_FIXED..end]), end)
                }
                b => return Err(FrameError::BadFlag(b)),
            };
            Ok(Frame::Envelope(Envelope {
                from,
                step,
                slot,
                class,
                payload: body[payload_at..].to_vec().into(),
                broadcast,
                deliver_at: 0,
                signature,
            }))
        }
        k => Err(FrameError::BadKind(k)),
    }
}

/// Incremental frame decoder: feed it whatever the socket hands you —
/// one byte at a time, half a frame, three frames at once — and pull
/// complete frames out. Oversized length prefixes are rejected *before*
/// the body is buffered; every decode error is connection-fatal (a TCP
/// stream with a corrupt frame has no resynchronization point).
pub struct FrameReader {
    buf: Vec<u8>,
    max_frame: usize,
}

impl FrameReader {
    pub fn new(max_frame: usize) -> FrameReader {
        FrameReader { buf: Vec::new(), max_frame }
    }

    pub fn feed(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Next complete frame, `Ok(None)` if more bytes are needed.
    pub fn next_frame(&mut self) -> Result<Option<Frame>, FrameError> {
        if self.buf.len() < 8 {
            return Ok(None);
        }
        if self.buf[..4] != MAGIC {
            return Err(FrameError::BadMagic(self.buf[..4].try_into().unwrap()));
        }
        let len = u32::from_le_bytes(self.buf[4..8].try_into().unwrap()) as usize;
        if len > self.max_frame {
            return Err(FrameError::Oversized { len, max: self.max_frame });
        }
        if self.buf.len() < 8 + len {
            return Ok(None);
        }
        let frame = decode_body(&self.buf[8..8 + len])?;
        self.buf.drain(..8 + len);
        Ok(Some(frame))
    }
}

// ---------------------------------------------------------------------------
// Roster
// ---------------------------------------------------------------------------

/// One roster row: who a peer is and where it listens.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RosterEntry {
    pub id: PeerId,
    /// `host:port` the peer's listener is bound to.
    pub addr: String,
    pub pubkey: PublicKey,
}

/// The cluster roster: the one artifact socket peers share out of band.
/// Ids must be the contiguous range `0..n` (they index the partition
/// map, the ban ledger and the signature table, exactly like in-process
/// peer ids).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Roster {
    pub peers: Vec<RosterEntry>,
}

impl Roster {
    pub fn n(&self) -> usize {
        self.peers.len()
    }

    /// Parse and validate a roster JSON document:
    /// `{"peers": [{"id": 0, "addr": "127.0.0.1:9000", "pubkey": "<64 hex>"}, …]}`.
    pub fn parse(text: &str) -> Result<Roster, String> {
        let j = Json::parse(text)?;
        let arr = j
            .get("peers")
            .and_then(|v| v.as_arr())
            .ok_or("roster must be an object with a 'peers' array")?;
        let mut peers = Vec::with_capacity(arr.len());
        for p in arr {
            let id = p
                .get("id")
                .and_then(|v| v.as_usize())
                .ok_or("roster entry missing integer 'id'")?;
            let addr = p
                .get("addr")
                .and_then(|v| v.as_str())
                .ok_or("roster entry missing string 'addr'")?
                .to_string();
            if addr.is_empty() {
                return Err(format!("roster entry {id} has an empty addr"));
            }
            let pk_hex = p
                .get("pubkey")
                .and_then(|v| v.as_str())
                .ok_or("roster entry missing string 'pubkey'")?;
            let pk = unhex(pk_hex)
                .filter(|b| b.len() == 32)
                .ok_or_else(|| format!("roster entry {id}: pubkey must be 64 hex chars"))?;
            let mut key = [0u8; 32];
            key.copy_from_slice(&pk);
            peers.push(RosterEntry { id, addr, pubkey: PublicKey(key) });
        }
        if peers.len() < 2 {
            return Err("roster needs at least 2 peers".to_string());
        }
        peers.sort_by_key(|p| p.id);
        for (k, p) in peers.iter().enumerate() {
            if p.id != k {
                return Err(format!(
                    "roster ids must be the contiguous range 0..{} (missing or duplicate id {k})",
                    peers.len()
                ));
            }
        }
        Ok(Roster { peers })
    }

    pub fn to_json(&self) -> String {
        let peers: Vec<Json> = self
            .peers
            .iter()
            .map(|p| {
                Json::obj(vec![
                    ("id", Json::num(p.id as f64)),
                    ("addr", Json::str(&p.addr)),
                    ("pubkey", Json::str(&hex(&p.pubkey.0))),
                ])
            })
            .collect();
        Json::obj(vec![("peers", Json::Arr(peers))]).to_string_pretty()
    }

    pub fn load(path: &std::path::Path) -> Result<Roster, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading roster '{}': {e}", path.display()))?;
        Roster::parse(&text)
    }

    /// Atomic save (tmp + rename): a reader polling for the file never
    /// observes a half-written roster.
    pub fn save(&self, path: &std::path::Path) -> std::io::Result<()> {
        crate::util::atomic_write(path, &self.to_json())
    }
}

/// Deterministic per-peer keypair of a run: the exact derivation the
/// in-process cluster builder uses (`build_cluster` with
/// `key_seed = run_seed ^ 0xC1A5`). Deriving instead of generating is
/// what makes a socket run's signatures — and therefore its metrics
/// digest — bit-identical to the in-process run of the same seed.
/// Simulation-grade by design; a production roster would carry fresh
/// independently-generated keys.
pub fn derive_keypair(mont: &Mont, run_seed: u64, id: PeerId) -> SecretKey {
    keygen(mont, (run_seed ^ 0xC1A5) + id as u64)
}

/// Bind an ephemeral loopback listener, returning it with its concrete
/// `host:port` (the rendezvous flow publishes this in an addr file).
pub fn bind_ephemeral() -> std::io::Result<(TcpListener, String)> {
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    Ok((listener, addr))
}

// ---------------------------------------------------------------------------
// SocketNet
// ---------------------------------------------------------------------------

/// Socket-level knobs (the protocol-level ones stay in `RunConfig`).
#[derive(Clone, Debug)]
pub struct SocketConfig {
    pub gossip_fanout: u64,
    pub verify_signatures: bool,
    /// Budget for the whole mesh build: dial retries, accepts and both
    /// HELLO exchanges must finish within it.
    pub connect_timeout: Duration,
    pub max_frame: usize,
}

impl Default for SocketConfig {
    fn default() -> Self {
        SocketConfig {
            gossip_fanout: 8,
            verify_signatures: true,
            connect_timeout: Duration::from_secs(30),
            max_frame: DEFAULT_MAX_FRAME,
        }
    }
}

fn io_err(msg: String) -> std::io::Error {
    std::io::Error::new(ErrorKind::InvalidData, msg)
}

fn timeout_err(what: &str) -> std::io::Error {
    std::io::Error::new(ErrorKind::TimedOut, format!("socket mesh: timed out {what}"))
}

/// Dial with retry until the deadline: the target may not have bound its
/// listener yet (peers start in arbitrary order). Each attempt uses
/// `connect_timeout` bounded by the time left — a roster address behind
/// a packet-dropping firewall must fail at the configured deadline, not
/// after the OS's multi-minute default SYN timeout.
fn dial_with_retry(addr: &str, deadline: Instant) -> std::io::Result<TcpStream> {
    const ATTEMPT_CAP: Duration = Duration::from_secs(2);
    loop {
        let remaining = deadline.saturating_duration_since(Instant::now());
        if remaining.is_zero() {
            return Err(std::io::Error::new(
                ErrorKind::TimedOut,
                format!("dialing {addr}: deadline exceeded"),
            ));
        }
        let attempt = addr
            .to_socket_addrs()
            .and_then(|mut addrs| {
                addrs.next().ok_or_else(|| io_err(format!("'{addr}' resolves to no address")))
            })
            .and_then(|sa| TcpStream::connect_timeout(&sa, remaining.min(ATTEMPT_CAP)));
        match attempt {
            Ok(s) => return Ok(s),
            Err(e) => {
                if Instant::now() >= deadline {
                    return Err(std::io::Error::new(
                        ErrorKind::TimedOut,
                        format!("dialing {addr}: {e}"),
                    ));
                }
                thread::sleep(Duration::from_millis(30));
            }
        }
    }
}

/// Read one frame before the deadline, leaving any extra bytes in `fr`
/// (the remote may pipeline envelopes right behind its HELLO — those
/// bytes belong to the link's reader thread, which inherits `fr`).
fn read_frame_deadline(
    stream: &mut TcpStream,
    fr: &mut FrameReader,
    deadline: Instant,
) -> std::io::Result<Frame> {
    let mut buf = [0u8; 4096];
    loop {
        if let Some(frame) = fr.next_frame().map_err(|e| io_err(e.to_string()))? {
            return Ok(frame);
        }
        let remaining = deadline.saturating_duration_since(Instant::now());
        if remaining.is_zero() {
            return Err(timeout_err("waiting for a handshake frame"));
        }
        stream.set_read_timeout(Some(remaining))?;
        match stream.read(&mut buf) {
            Ok(0) => {
                return Err(std::io::Error::new(
                    ErrorKind::UnexpectedEof,
                    "connection closed during handshake",
                ))
            }
            Ok(k) => fr.feed(&buf[..k]),
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                return Err(timeout_err("waiting for a handshake frame"))
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
}

/// Per-connection slice of the accept loop's budget: a silent or
/// garbage inbound connection (port scanner, health probe, hostile
/// peer) is dropped after at most this long. Handshakes run on their
/// own threads, so a stalling connection costs only itself — never the
/// mesh build (see `accept_handshake`).
const HELLO_SLICE: Duration = Duration::from_secs(5);

/// Validate one inbound connection's HELLO against the roster. Errors
/// here condemn the *connection*, not the accept loop: the module
/// contract is that a hostile peer can kill its own link, never the
/// receiver — aborting the whole mesh build on a stray probe would hand
/// any port-scanner a denial of service. When the cluster verifies
/// signatures, the HELLO must carry a valid signature under the claimed
/// peer's roster key — an unsigned (or mis-signed) identity claim is
/// exactly the spoof this check exists to stop.
fn accept_handshake(
    stream: &mut TcpStream,
    fr: &mut FrameReader,
    deadline: Instant,
    me: PeerId,
    roster: &Roster,
    mont: &Mont,
    verify_signatures: bool,
) -> Result<Hello, String> {
    let frame = read_frame_deadline(stream, fr, deadline).map_err(|e| e.to_string())?;
    let h = match frame {
        Frame::Hello(h) => h,
        Frame::Envelope(_) => return Err("envelope before HELLO".to_string()),
    };
    if h.id == me || h.id >= roster.n() {
        return Err(format!("HELLO claims peer {} (not a valid remote of peer {me})", h.id));
    }
    if h.pubkey != roster.peers[h.id].pubkey {
        return Err(format!("HELLO pubkey for peer {} does not match the roster", h.id));
    }
    if verify_signatures {
        let Some(sig) = &h.signature else {
            return Err(format!("unsigned HELLO claiming peer {}", h.id));
        };
        if !verify(mont, &roster.peers[h.id].pubkey, &hello_signing_bytes(h.id), sig) {
            return Err(format!("HELLO signature for peer {} does not verify", h.id));
        }
    }
    Ok(h)
}

/// Transport-level frame admission on an authenticated link: only
/// envelope frames whose `from` matches the link's peer pass. Everything
/// else — a second HELLO, a spoofed sender — is a protocol violation
/// that kills the link (returns `None`).
pub(crate) fn admit_frame(frame: Frame, link_peer: PeerId) -> Option<Envelope> {
    match frame {
        Frame::Envelope(env) if env.from == link_peer => Some(env),
        _ => None,
    }
}

/// Per-link reader: decode frames into the shared mailbox until the
/// connection closes or misbehaves. Runs with no read timeout — the
/// protocol's own receive timeouts decide when silence becomes a
/// violation.
fn reader_loop(
    mut stream: TcpStream,
    mut fr: FrameReader,
    link_peer: PeerId,
    tx: Sender<Envelope>,
) {
    let _ = stream.set_read_timeout(None);
    let mut buf = [0u8; 65536];
    loop {
        // Drain every complete frame already buffered (the handshake may
        // have left some) before touching the socket again.
        loop {
            match fr.next_frame() {
                Ok(Some(frame)) => match admit_frame(frame, link_peer) {
                    Some(env) => {
                        if tx.send(env).is_err() {
                            return; // endpoint dropped — we're shutting down
                        }
                    }
                    None => {
                        // Spoofed sender or post-handshake HELLO: the link
                        // is hostile or corrupt; close it. The protocol
                        // sees the peer as silent and ELIMINATEs it.
                        let _ = stream.shutdown(Shutdown::Both);
                        return;
                    }
                },
                Ok(None) => break,
                Err(_) => {
                    let _ = stream.shutdown(Shutdown::Both);
                    return;
                }
            }
        }
        match stream.read(&mut buf) {
            Ok(0) => return, // EOF: peer exited (banned / finished)
            Ok(k) => fr.feed(&buf[..k]),
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(_) => return,
        }
    }
}

/// A real-socket transport endpoint: one send-direction TCP connection
/// per ordered peer pair, a reader thread per inbound link, and the
/// shared [`Inbox`] delivery semantics.
pub struct SocketNet {
    id: PeerId,
    info: Arc<ClusterInfo>,
    secret: SecretKey,
    mont: Mont,
    /// Outbound (send-only) links, indexed by peer id (`None` at our own
    /// slot). Nothing is ever read from these.
    links: Vec<Option<Arc<Mutex<TcpStream>>>>,
    /// Shutdown handles for the inbound (receive-only) links, so `Drop`
    /// can unblock the reader threads before joining them.
    inbound: Vec<TcpStream>,
    /// Self-delivery: loopback never crosses the network.
    loopback: Sender<Envelope>,
    inbox: Inbox,
    timeout: Duration,
    recv_mode: RecvMode,
    readers: Vec<thread::JoinHandle<()>>,
}

impl SocketNet {
    /// Build this peer's endpoint of the mesh: dial every other peer's
    /// listener once (opening our send-direction link, prefixed by our
    /// HELLO), then accept every other peer's send-direction link
    /// (validating its HELLO against the roster) and spawn its reader
    /// thread. `listener` must already be bound to
    /// `roster.peers[id].addr` (bind-before-publish is what the
    /// rendezvous flow guarantees).
    ///
    /// No HELLO replies are exchanged: a dialer that waited for one
    /// while its own acceptor was idle would deadlock the all-dial-first
    /// build order, and the reply authenticated nothing the envelope
    /// signatures don't already. A misrouted roster address surfaces as
    /// the far end rejecting the HELLO (or dropping every forged
    /// envelope), never as silent misdelivery.
    pub fn connect(
        listener: TcpListener,
        roster: &Roster,
        id: PeerId,
        secret: SecretKey,
        cfg: &SocketConfig,
    ) -> std::io::Result<SocketNet> {
        let n = roster.n();
        if id >= n {
            return Err(io_err(format!("peer id {id} outside the {n}-peer roster")));
        }
        if secret.public != roster.peers[id].pubkey {
            return Err(io_err(format!(
                "peer {id}: secret key does not match the roster's pubkey"
            )));
        }
        let mont = Mont::new();
        let info = Arc::new(ClusterInfo {
            n_peers: n,
            public_keys: roster.peers.iter().map(|p| p.pubkey).collect(),
            stats: TrafficStats::new(n, cfg.gossip_fanout),
            verify_signatures: cfg.verify_signatures,
        });
        let (tx, rx) = channel();
        let deadline = Instant::now() + cfg.connect_timeout;
        let hello = encode_hello(id, &secret, &mont, cfg.verify_signatures);

        // Outbound links: dial every other peer and announce ourselves.
        // TCP completes the connect via the listener's backlog whether or
        // not the remote has reached its accept loop yet, so the
        // all-dials-then-all-accepts order cannot deadlock.
        let mut links: Vec<Option<Arc<Mutex<TcpStream>>>> = (0..n).map(|_| None).collect();
        for (j, link) in links.iter_mut().enumerate() {
            if j == id {
                continue;
            }
            let mut stream = dial_with_retry(&roster.peers[j].addr, deadline)?;
            let _ = stream.set_nodelay(true);
            stream.write_all(&hello)?;
            *link = Some(Arc::new(Mutex::new(stream)));
        }

        // Inbound links: accept one send-direction connection from every
        // other peer, validate its HELLO, and hand it (plus any bytes
        // the sender pipelined right behind the HELLO) to a reader.
        // Handshakes run on their own short-lived threads so a silent or
        // hostile connection stalls only itself for its HELLO_SLICE —
        // probes must not be able to serialize away the accept budget.
        listener.set_nonblocking(true)?;
        let (hs_tx, hs_rx) = channel::<Result<(Hello, TcpStream, FrameReader), String>>();
        let mut inbound = Vec::with_capacity(n - 1);
        let mut readers = Vec::with_capacity(n - 1);
        let mut seen = vec![false; n];
        while inbound.len() < n - 1 {
            // Take new connections without blocking.
            match listener.accept() {
                Ok((stream, _)) => {
                    let hello_deadline = (Instant::now() + HELLO_SLICE).min(deadline);
                    let hs_tx = hs_tx.clone();
                    let roster = roster.clone();
                    let max_frame = cfg.max_frame;
                    let verify_sigs = cfg.verify_signatures;
                    thread::Builder::new()
                        .name(format!("sock-handshake-{id}"))
                        .spawn(move || {
                            let mut stream = stream;
                            let result = stream
                                .set_nonblocking(false)
                                .map_err(|e| e.to_string())
                                .and_then(|()| {
                                    let _ = stream.set_nodelay(true);
                                    let mont = Mont::new();
                                    let mut fr = FrameReader::new(max_frame);
                                    accept_handshake(
                                        &mut stream,
                                        &mut fr,
                                        hello_deadline,
                                        id,
                                        &roster,
                                        &mont,
                                        verify_sigs,
                                    )
                                    .map(|h| (h, fr))
                                });
                            let _ = match result {
                                Ok((h, fr)) => hs_tx.send(Ok((h, stream, fr))),
                                Err(reason) => {
                                    let _ = stream.shutdown(Shutdown::Both);
                                    hs_tx.send(Err(reason))
                                }
                            };
                        })
                        .map_err(|e| io_err(format!("spawning handshake thread: {e}")))?;
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {}
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) => return Err(e),
            }
            // Install every handshake that completed meanwhile.
            while let Ok(result) = hs_rx.try_recv() {
                match result {
                    Ok((h, stream, fr)) if !seen[h.id] => {
                        seen[h.id] = true;
                        stream.set_read_timeout(None)?;
                        let read_half = stream.try_clone()?;
                        let link_tx = tx.clone();
                        let peer = h.id;
                        let handle = thread::Builder::new()
                            .name(format!("sock-reader-{id}-from-{peer}"))
                            .spawn(move || reader_loop(read_half, fr, peer, link_tx))
                            .map_err(|e| io_err(format!("spawning reader thread: {e}")))?;
                        readers.push(handle);
                        inbound.push(stream);
                    }
                    Ok((h, stream, _)) => {
                        // Duplicate claim (a replayed HELLO, or a bug):
                        // the first connection won; drop this one.
                        eprintln!(
                            "socket mesh (peer {id}): dropping duplicate connection claiming \
                             peer {}",
                            h.id
                        );
                        let _ = stream.shutdown(Shutdown::Both);
                    }
                    Err(reason) => {
                        // Doomed connection, already shut down by its
                        // handshake thread; keep accepting. A legitimate
                        // peer lost here surfaces as the overall accept
                        // timeout below.
                        eprintln!(
                            "socket mesh (peer {id}): dropping inbound connection: {reason}"
                        );
                    }
                }
            }
            if inbound.len() < n - 1 {
                if Instant::now() >= deadline {
                    return Err(timeout_err(&format!(
                        "waiting for {} inbound connection(s)",
                        n - 1 - inbound.len()
                    )));
                }
                thread::sleep(Duration::from_millis(5));
            }
        }

        Ok(SocketNet {
            id,
            info,
            secret,
            mont,
            links,
            inbound,
            loopback: tx,
            inbox: Inbox::new(rx),
            timeout: Duration::from_secs(30),
            recv_mode: RecvMode::Blocking,
            readers,
        })
    }

    fn make_envelope(
        &self,
        step: u64,
        slot: u32,
        class: MsgClass,
        payload: Vec<u8>,
        broadcast: bool,
    ) -> Envelope {
        let mut env = Envelope {
            from: self.id,
            step,
            slot,
            class,
            payload: payload.into(),
            broadcast,
            deliver_at: 0,
            signature: None,
        };
        if self.info.verify_signatures {
            env.sign_with(&self.mont, &self.secret);
        }
        env
    }

    /// Write a pre-encoded frame to a link, ignoring errors: the remote
    /// may have been banned or finished early, exactly like the perfect
    /// fabric's ignored channel-send errors.
    fn write_link(&self, to: PeerId, frame: &[u8]) {
        if let Some(link) = &self.links[to] {
            if let Ok(mut stream) = link.lock() {
                let _ = stream.write_all(frame);
            }
        }
    }
}

impl Drop for SocketNet {
    fn drop(&mut self) {
        // Outbound links carry no inbound data, so closing them reaches
        // the remote as a clean FIN after everything we sent — an
        // early-exiting (banned) peer can never RST away envelopes an
        // honest receiver has not yet drained.
        for link in self.links.iter().flatten() {
            if let Ok(stream) = link.lock() {
                let _ = stream.shutdown(Shutdown::Both);
            }
        }
        // Shutting down the inbound links unblocks every reader thread
        // parked in read(), so the joins below cannot hang. Any RST this
        // provokes lands on the remote's send-only socket, where there
        // is nothing to lose.
        for stream in &self.inbound {
            let _ = stream.shutdown(Shutdown::Both);
        }
        for handle in self.readers.drain(..) {
            let _ = handle.join();
        }
    }
}

impl Transport for SocketNet {
    fn id(&self) -> PeerId {
        self.id
    }

    fn info(&self) -> &Arc<ClusterInfo> {
        &self.info
    }

    fn set_timeout(&mut self, timeout: Duration) {
        self.timeout = timeout;
    }

    fn set_recv_mode(&mut self, mode: RecvMode) {
        self.recv_mode = mode;
    }

    fn tick(&mut self) {
        self.inbox.advance_clock(self.recv_mode);
    }

    fn send(&mut self, to: PeerId, step: u64, slot: u32, class: MsgClass, payload: Vec<u8>) {
        let bytes = payload.len();
        let env = self.make_envelope(step, slot, class, payload, false);
        self.info.stats.record_p2p(self.id, class, bytes);
        if to == self.id {
            let _ = self.loopback.send(env);
        } else {
            self.write_link(to, &encode_envelope(&env));
        }
    }

    fn broadcast(&mut self, step: u64, slot: u32, class: MsgClass, payload: Vec<u8>) {
        let bytes = payload.len();
        let env = self.make_envelope(step, slot, class, payload, true);
        self.info.stats.record_broadcast(self.id, class, bytes);
        let frame = encode_envelope(&env);
        let _ = self.loopback.send(env);
        for to in 0..self.info.n_peers {
            if to != self.id {
                self.write_link(to, &frame);
            }
        }
    }

    fn broadcast_split(
        &mut self,
        step: u64,
        slot: u32,
        class: MsgClass,
        variants: Vec<(PeerId, Vec<u8>)>,
    ) {
        // Same distinct-variant relay semantics as every other backend:
        // each variant eventually reaches every peer.
        for payload in distinct_variants(&variants) {
            self.broadcast(step, slot, class, payload);
        }
    }

    fn recv_keyed(
        &mut self,
        step: u64,
        slot: u32,
        pred: &dyn Fn(&Envelope) -> bool,
    ) -> Result<Envelope, RecvError> {
        self.inbox.recv_keyed(
            &self.info,
            &self.mont,
            self.recv_mode,
            self.timeout,
            step,
            slot,
            pred,
        )
    }

    fn drain_match(&mut self, pred: &dyn Fn(&Envelope) -> bool) -> Vec<Envelope> {
        self.inbox.drain_match(&self.info, &self.mont, self.recv_mode, pred)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::slots;

    fn sample_envelope(signed: bool) -> Envelope {
        let mont = Mont::new();
        let sk = keygen(&mont, 42);
        let mut env = Envelope {
            from: 3,
            step: 17,
            slot: slots::sub(slots::GRAD_PART, 5),
            class: MsgClass::GradientPart,
            payload: vec![1, 2, 3, 4, 5].into(),
            broadcast: false,
            deliver_at: 0,
            signature: None,
        };
        if signed {
            env.sign_with(&mont, &sk);
        }
        env
    }

    fn assert_envelope_eq(a: &Envelope, b: &Envelope) {
        assert_eq!(a.from, b.from);
        assert_eq!(a.step, b.step);
        assert_eq!(a.slot, b.slot);
        assert_eq!(a.class, b.class);
        assert_eq!(a.broadcast, b.broadcast);
        assert_eq!(a.payload.to_vec(), b.payload.to_vec());
        assert_eq!(a.signature, b.signature);
        assert_eq!(b.deliver_at, 0, "wire envelopes are stamped for immediate delivery");
    }

    #[test]
    fn envelope_frame_roundtrip_signed_and_unsigned() {
        for signed in [false, true] {
            let env = sample_envelope(signed);
            let mut fr = FrameReader::new(DEFAULT_MAX_FRAME);
            fr.feed(&encode_envelope(&env));
            match fr.next_frame().unwrap() {
                Some(Frame::Envelope(got)) => assert_envelope_eq(&env, &got),
                other => panic!("expected envelope, got {other:?}"),
            }
            assert!(fr.next_frame().unwrap().is_none());
        }
    }

    #[test]
    fn hello_frame_roundtrip_signed_and_unsigned() {
        let mont = Mont::new();
        let sk = keygen(&mont, 7);
        for signed in [false, true] {
            let mut fr = FrameReader::new(DEFAULT_MAX_FRAME);
            fr.feed(&encode_hello(12, &sk, &mont, signed));
            match fr.next_frame().unwrap() {
                Some(Frame::Hello(h)) => {
                    assert_eq!(h.id, 12);
                    assert_eq!(h.pubkey, sk.public);
                    assert_eq!(h.signature.is_some(), signed);
                    if let Some(sig) = &h.signature {
                        // The signature binds the claimed id to the
                        // roster key — the anti-spoof check of
                        // accept_handshake.
                        assert!(verify(&mont, &sk.public, &hello_signing_bytes(12), sig));
                        assert!(!verify(&mont, &sk.public, &hello_signing_bytes(13), sig));
                    }
                }
                other => panic!("expected hello, got {other:?}"),
            }
        }
    }

    #[test]
    fn reader_reassembles_byte_at_a_time_and_back_to_back_frames() {
        let a = sample_envelope(true);
        let b = sample_envelope(false);
        let mut bytes = encode_envelope(&a);
        bytes.extend_from_slice(&encode_envelope(&b));
        let mut fr = FrameReader::new(DEFAULT_MAX_FRAME);
        let mut got = Vec::new();
        for byte in &bytes {
            fr.feed(std::slice::from_ref(byte));
            while let Some(frame) = fr.next_frame().unwrap() {
                got.push(frame);
            }
        }
        assert_eq!(got.len(), 2);
        match (&got[0], &got[1]) {
            (Frame::Envelope(x), Frame::Envelope(y)) => {
                assert_envelope_eq(&a, x);
                assert_envelope_eq(&b, y);
            }
            other => panic!("expected two envelopes, got {other:?}"),
        }
    }

    #[test]
    fn oversized_frames_are_rejected_before_buffering() {
        let mut fr = FrameReader::new(1024);
        let mut header = Vec::new();
        header.extend_from_slice(&MAGIC);
        header.extend_from_slice(&(1_000_000u32).to_le_bytes());
        fr.feed(&header);
        assert_eq!(
            fr.next_frame().unwrap_err(),
            FrameError::Oversized { len: 1_000_000, max: 1024 }
        );
    }

    #[test]
    fn garbage_prefix_is_rejected() {
        let mut fr = FrameReader::new(1024);
        fr.feed(b"GET / HTTP/1.1\r\n");
        assert!(matches!(fr.next_frame(), Err(FrameError::BadMagic(_))));
    }

    #[test]
    fn malformed_bodies_are_rejected() {
        let frame_with_body = |body: &[u8]| {
            let mut out = Vec::new();
            out.extend_from_slice(&MAGIC);
            out.extend_from_slice(&(body.len() as u32).to_le_bytes());
            out.extend_from_slice(body);
            out
        };
        // Unknown kind.
        let mut fr = FrameReader::new(1024);
        fr.feed(&frame_with_body(&[9, 0, 0]));
        assert_eq!(fr.next_frame().unwrap_err(), FrameError::BadKind(9));
        // Envelope body shorter than its fixed fields.
        let mut fr = FrameReader::new(1024);
        fr.feed(&frame_with_body(&[KIND_ENVELOPE, 0, 0, 0]));
        assert!(matches!(fr.next_frame(), Err(FrameError::Truncated { .. })));
        // Bad message class.
        let env = sample_envelope(false);
        let mut bytes = encode_envelope(&env);
        bytes[8 + 21] = 99; // class byte
        let mut fr = FrameReader::new(1024);
        fr.feed(&bytes);
        assert_eq!(fr.next_frame().unwrap_err(), FrameError::BadClass(99));
        // Bad signature flag.
        let mut bytes = encode_envelope(&env);
        bytes[8 + 23] = 7; // sig flag
        let mut fr = FrameReader::new(1024);
        fr.feed(&bytes);
        assert_eq!(fr.next_frame().unwrap_err(), FrameError::BadFlag(7));
        // Signed flag set but signature bytes missing.
        let truncated = frame_with_body(&{
            let mut body = vec![KIND_ENVELOPE];
            body.extend_from_slice(&3u64.to_le_bytes());
            body.extend_from_slice(&0u64.to_le_bytes());
            body.extend_from_slice(&slots::GRAD_PART.to_le_bytes());
            body.push(MsgClass::GradientPart as u8);
            body.push(0);
            body.push(1); // signed, but no signature follows
            body
        });
        let mut fr = FrameReader::new(1024);
        fr.feed(&truncated);
        assert!(matches!(fr.next_frame(), Err(FrameError::Truncated { .. })));
    }

    #[test]
    fn admit_frame_enforces_link_identity() {
        let env = sample_envelope(false); // from = 3
        assert!(admit_frame(Frame::Envelope(env.clone()), 3).is_some());
        // Spoofed sender: the frame claims a peer other than the link's.
        assert!(admit_frame(Frame::Envelope(env), 2).is_none());
        // HELLO after the handshake is a protocol violation.
        let mont = Mont::new();
        let sk = keygen(&mont, 1);
        let hello = Hello { id: 3, pubkey: sk.public, signature: None };
        assert!(admit_frame(Frame::Hello(hello), 3).is_none());
    }

    #[test]
    fn roster_roundtrip_and_validation() {
        let mont = Mont::new();
        let peers: Vec<RosterEntry> = (0..3)
            .map(|k| RosterEntry {
                id: k,
                addr: format!("127.0.0.1:{}", 9000 + k),
                pubkey: derive_keypair(&mont, 7, k).public,
            })
            .collect();
        let roster = Roster { peers };
        let parsed = Roster::parse(&roster.to_json()).unwrap();
        assert_eq!(parsed, roster);
        // Non-contiguous ids are rejected.
        let mut bad = roster.clone();
        bad.peers[2].id = 5;
        assert!(Roster::parse(&bad.to_json()).is_err());
        // Malformed pubkey hex is rejected.
        assert!(Roster::parse(
            r#"{"peers": [{"id": 0, "addr": "a:1", "pubkey": "zz"},
                           {"id": 1, "addr": "a:2", "pubkey": "00"}]}"#
        )
        .is_err());
        // A single peer is not a cluster.
        assert!(Roster::parse(r#"{"peers": [{"id": 0, "addr": "a:1", "pubkey": ""}]}"#).is_err());
    }

    #[test]
    fn derive_keypair_matches_in_process_builder() {
        // build_cluster(n, key_seed, …) derives peer k's key from
        // key_seed + k with key_seed = run_seed ^ 0xC1A5; the socket
        // path must agree or signatures (and digests) diverge.
        let mont = Mont::new();
        let run_seed = 7u64;
        let cluster = crate::net::build_cluster(3, run_seed ^ 0xC1A5, 8, true);
        for (k, peer) in cluster.iter().enumerate() {
            assert_eq!(derive_keypair(&mont, run_seed, k).public, peer.info.public_keys[k]);
        }
    }

    #[test]
    fn two_peer_socket_mesh_exchanges_signed_envelopes() {
        let mont = Mont::new();
        let (l0, a0) = bind_ephemeral().unwrap();
        let (l1, a1) = bind_ephemeral().unwrap();
        let roster = Roster {
            peers: vec![
                RosterEntry { id: 0, addr: a0, pubkey: derive_keypair(&mont, 5, 0).public },
                RosterEntry { id: 1, addr: a1, pubkey: derive_keypair(&mont, 5, 1).public },
            ],
        };
        let cfg = SocketConfig { connect_timeout: Duration::from_secs(10), ..Default::default() };
        let r1 = roster.clone();
        let c1 = cfg.clone();
        let t1 = std::thread::spawn(move || {
            let mont = Mont::new();
            let mut net = SocketNet::connect(l1, &r1, 1, derive_keypair(&mont, 5, 1), &c1).unwrap();
            net.send(0, 2, slots::GRAD_PART, MsgClass::GradientPart, vec![42]);
            net.broadcast(2, slots::GRAD_COMMIT, MsgClass::Commitment, vec![7, 8]);
            // Wait for peer 0's reply before dropping the endpoint.
            let env = net.recv_keyed(2, slots::VERIFY_SCALARS, &|_| true).unwrap();
            assert_eq!(env.from, 0);
            assert_eq!(env.payload.to_vec(), vec![9]);
        });
        let mut net0 =
            SocketNet::connect(l0, &roster, 0, derive_keypair(&mont, 5, 0), &cfg).unwrap();
        net0.set_timeout(Duration::from_secs(10));
        let p2p = net0.recv_keyed(2, slots::GRAD_PART, &|e| e.from == 1).unwrap();
        assert_eq!(p2p.payload.to_vec(), vec![42]);
        assert!(p2p.signature.is_some(), "wire envelopes are signed when verification is on");
        let bc = net0.recv_keyed(2, slots::GRAD_COMMIT, &|e| e.from == 1).unwrap();
        assert_eq!(bc.payload.to_vec(), vec![7, 8]);
        assert!(bc.broadcast);
        net0.send(1, 2, slots::VERIFY_SCALARS, MsgClass::Verification, vec![9]);
        t1.join().unwrap();
        // Sender-side traffic accounting matches the perfect fabric's
        // (payload bytes, not frame bytes; broadcasts pay the fanout).
        assert_eq!(net0.info().stats.total_bytes(0), 1);
    }
}
