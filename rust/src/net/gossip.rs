//! Gossip dissemination: the deterministic broadcast overlay and
//! broadcast-channel consistency (equivocation detection).
//!
//! Two layers live here:
//!
//! - [`Overlay`] — the relay graph a gossip-mode socket cluster uses for
//!   broadcast traffic. It is a **pure function of (epoch roster, seed,
//!   fanout)**, derived exactly like [`OwnerMap::derive`]: the sorted
//!   roster is shuffled by a seeded permutation into a ring, and each
//!   peer's out-neighbours are the ring positions at doubling strides
//!   (+1, +2, +4, …) capped at `fanout`. Out-degree is therefore
//!   ≤ min(fanout, ⌈log₂ n⌉), in-degree equals out-degree by stride
//!   symmetry, and the {+1, +2} strides alone keep the graph strongly
//!   connected through any single crashed relay. Every peer derives the
//!   identical graph from config data — no timing, no negotiation.
//!
//! - [`RelayTracker`] / [`EquivocationTracker`] — the relay-once rule and
//!   its protocol-level sibling. The paper (footnote 4) requires that a
//!   peer broadcasting two contradicting messages for the same protocol
//!   slot be banned, because different honest peers might otherwise act
//!   on different values. The transport relays each *distinct payload*
//!   for a (origin, step, slot) key exactly once — duplicates are
//!   dropped, but a contradicting second variant is still delivered and
//!   relayed, because every honest peer must see both signed variants to
//!   reproduce the same ban evidence the full mesh would have produced.
//!   [`EquivocationTracker`] records first-seen digests per slot at the
//!   protocol layer and flags any signed contradiction as ban evidence.
//!
//! [`OwnerMap::derive`]: crate::coordinator::partition::OwnerMap::derive

use std::collections::HashMap;

use super::{Envelope, PeerId};
use crate::crypto::{sha256, sha256_parts};
use crate::util::rng::Rng;

// ---------------------------------------------------------------------------
// Deterministic broadcast overlay
// ---------------------------------------------------------------------------

/// The gossip relay graph for one membership epoch: who dials whom for
/// broadcast traffic. Derived identically by every peer from pure config
/// data; see the module docs for the construction.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Overlay {
    /// Epoch roster, sorted and deduplicated.
    members: Vec<PeerId>,
    /// `out[i]` = out-neighbours of `members[i]`, in relay order.
    out: Vec<Vec<PeerId>>,
}

impl Overlay {
    /// Derive the epoch's relay graph: a **pure function of the epoch
    /// roster, seed, epoch index, and fanout** — independent of input
    /// order, execution model, worker count, or the path by which the
    /// roster was reached (property-pinned like `OwnerMap::derive`).
    pub fn derive(live: &[PeerId], global_seed: u64, epoch: u64, fanout: usize) -> Overlay {
        assert!(!live.is_empty(), "cannot derive an overlay for an empty roster");
        let mut roster: Vec<PeerId> = live.to_vec();
        roster.sort_unstable();
        roster.dedup();
        let n = roster.len();

        let mut seed_input: Vec<u8> = Vec::with_capacity(24 + n * 8);
        seed_input.extend_from_slice(&global_seed.to_le_bytes());
        seed_input.extend_from_slice(&epoch.to_le_bytes());
        seed_input.extend_from_slice(&(fanout as u64).to_le_bytes());
        for &p in &roster {
            seed_input.extend_from_slice(&(p as u64).to_le_bytes());
        }
        let digest = sha256_parts(&[b"btard-overlay", &seed_input]);
        let mut rng = Rng::from_digest(&digest);
        let mut ring = roster.clone();
        rng.shuffle(&mut ring);

        // ring position of each member (indexed like `roster`).
        let mut pos = vec![0usize; n];
        for (i, &p) in ring.iter().enumerate() {
            if let Ok(k) = roster.binary_search(&p) {
                pos[k] = i;
            }
        }

        let mut out = Vec::with_capacity(n);
        for member in 0..n {
            let i = pos[member];
            let mut nbrs: Vec<PeerId> = Vec::new();
            let mut stride = 1usize;
            while stride < n && nbrs.len() < fanout {
                let cand = ring[(i + stride) % n];
                if cand != roster[member] && !nbrs.contains(&cand) {
                    nbrs.push(cand);
                }
                stride *= 2;
            }
            out.push(nbrs);
        }
        Overlay { members: roster, out }
    }

    /// The epoch roster (sorted).
    pub fn members(&self) -> &[PeerId] {
        &self.members
    }

    pub fn contains(&self, id: PeerId) -> bool {
        self.members.binary_search(&id).is_ok()
    }

    /// Peers `id` dials and relays broadcasts to. Empty for non-members.
    pub fn out_neighbors(&self, id: PeerId) -> &[PeerId] {
        match self.members.binary_search(&id) {
            Ok(k) => &self.out[k],
            Err(_) => &[],
        }
    }

    /// Peers expected to dial `id` (the inverse edge set) — what the
    /// accept side of a gossip mesh build waits for.
    pub fn in_neighbors(&self, id: PeerId) -> Vec<PeerId> {
        let mut v: Vec<PeerId> = self
            .members
            .iter()
            .zip(self.out.iter())
            .filter(|(_, nbrs)| nbrs.contains(&id))
            .map(|(&m, _)| m)
            .collect();
        v.sort_unstable();
        v
    }

    /// Max out-degree across the roster (the bench's link-count claim).
    pub fn max_out_degree(&self) -> usize {
        self.out.iter().map(Vec::len).max().unwrap_or(0)
    }
}

/// The overlays of every membership epoch, precomputed from config data
/// so relays at step `s` use the epoch that contains `s` — overlays are
/// **not** re-derived on bans, which are timing-dependent; robustness to
/// dead relays comes from the redundant strides instead.
#[derive(Clone, Debug)]
pub struct OverlaySchedule {
    /// `(first_step, overlay)`, sorted by `first_step`; entry 0 is step 0.
    epochs: Vec<(u64, Overlay)>,
}

impl OverlaySchedule {
    /// Build from the epoch table: `(first_step, live roster)` per epoch.
    /// The first entry must start at step 0.
    pub fn derive(
        epochs: &[(u64, Vec<PeerId>)],
        global_seed: u64,
        fanout: usize,
    ) -> OverlaySchedule {
        assert!(!epochs.is_empty(), "overlay schedule needs at least one epoch");
        assert_eq!(epochs[0].0, 0, "overlay epoch table must start at step 0");
        let built = epochs
            .iter()
            .enumerate()
            .map(|(e, (start, live))| (*start, Overlay::derive(live, global_seed, e as u64, fanout)))
            .collect();
        OverlaySchedule { epochs: built }
    }

    /// The overlay governing broadcasts at `step`.
    pub fn overlay_at(&self, step: u64) -> &Overlay {
        let i = match self.epochs.binary_search_by_key(&step, |&(s, _)| s) {
            Ok(i) => i,
            Err(i) => i - 1,
        };
        &self.epochs[i].1
    }

    /// Union of out-neighbours across all epochs — the links a peer may
    /// ever need to dial for relaying (the mesh build dials epoch 0's;
    /// later epochs' form lazily at the boundary).
    pub fn all_out_neighbors(&self, id: PeerId) -> Vec<PeerId> {
        let mut v: Vec<PeerId> = self
            .epochs
            .iter()
            .flat_map(|(_, o)| o.out_neighbors(id).iter().copied())
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }
}

// ---------------------------------------------------------------------------
// Relay-once dedup (transport layer)
// ---------------------------------------------------------------------------

/// What a relay should do with an observed broadcast.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Seen {
    /// First sighting of this payload for its (origin, step, slot):
    /// deliver locally and relay to the overlay out-neighbours.
    First,
    /// Byte-identical to a payload already seen for this key: drop.
    Duplicate,
    /// A *different* payload for a key that already has one — signed
    /// equivocation. Deliver **and relay** anyway: every honest peer
    /// must observe both variants to produce the same ban evidence the
    /// full mesh would have (`distinct_variants` semantics).
    Contradiction(Equivocation),
}

/// Payload variants remembered per (origin, step, slot). Two is enough
/// to convict; the cap bounds memory against a Byzantine origin flooding
/// unlimited variants (it is banned long before the cap matters).
const MAX_VARIANTS: usize = 4;

/// The transport-side relay-once filter: tracks every payload digest per
/// (origin, step, slot) so each distinct variant crosses each overlay
/// edge at most once. Lives inside the socket engine; the protocol-level
/// [`EquivocationTracker`] in the step machine stays the adjudication
/// source of truth.
#[derive(Default)]
pub struct RelayTracker {
    seen: HashMap<(PeerId, u64, u32), Vec<[u8; 32]>>,
}

impl RelayTracker {
    pub fn new() -> Self {
        Self::default()
    }

    /// Classify a broadcast envelope. Non-broadcast envelopes are never
    /// relayed and are not tracked; they classify as [`Seen::First`].
    pub fn observe(&mut self, env: &Envelope) -> Seen {
        if !env.broadcast {
            return Seen::First;
        }
        self.observe_digest(env.from, env.step, env.slot, sha256(&env.payload))
    }

    /// Digest-level entry point: the origin calls this at broadcast time
    /// to mark its own payloads seen, so copies echoed back through the
    /// overlay are dropped instead of re-relayed.
    pub fn observe_digest(&mut self, from: PeerId, step: u64, slot: u32, digest: [u8; 32]) -> Seen {
        let variants = self.seen.entry((from, step, slot)).or_default();
        if variants.contains(&digest) {
            return Seen::Duplicate;
        }
        if variants.len() >= MAX_VARIANTS {
            // Flooding origin: stop relaying new variants; evidence for a
            // ban has long been on every honest peer's wire.
            return Seen::Duplicate;
        }
        let first = variants.is_empty();
        variants.push(digest);
        if first {
            Seen::First
        } else {
            Seen::Contradiction(Equivocation { peer: from, step, slot })
        }
    }

    /// Drop state from steps older than `horizon` (bounded memory).
    pub fn gc(&mut self, current_step: u64, horizon: u64) {
        self.seen
            .retain(|&(_, step, _), _| step + horizon >= current_step);
    }

    pub fn len(&self) -> usize {
        self.seen.len()
    }

    pub fn is_empty(&self) -> bool {
        self.seen.is_empty()
    }
}

// ---------------------------------------------------------------------------
// Protocol-level equivocation evidence
// ---------------------------------------------------------------------------

/// Evidence that a peer equivocated: two distinct signed payloads for the
/// same broadcast slot.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Equivocation {
    pub peer: PeerId,
    pub step: u64,
    pub slot: u32,
}

#[derive(Default)]
pub struct EquivocationTracker {
    seen: HashMap<(PeerId, u64, u32), [u8; 32]>,
}

impl EquivocationTracker {
    pub fn new() -> Self {
        Self::default()
    }

    /// Observe a broadcast envelope. Returns equivocation evidence if this
    /// sender already broadcast different bytes in the same slot.
    pub fn observe(&mut self, env: &Envelope) -> Option<Equivocation> {
        if !env.broadcast {
            return None;
        }
        let digest = sha256(&env.payload);
        let key = (env.from, env.step, env.slot);
        match self.seen.get(&key) {
            None => {
                self.seen.insert(key, digest);
                None
            }
            Some(prev) if *prev == digest => None,
            Some(_) => Some(Equivocation { peer: env.from, step: env.step, slot: env.slot }),
        }
    }

    /// Drop state from steps older than `horizon` (bounded memory).
    pub fn gc(&mut self, current_step: u64, horizon: u64) {
        self.seen
            .retain(|&(_, step, _), _| step + horizon >= current_step);
    }

    pub fn len(&self) -> usize {
        self.seen.len()
    }

    pub fn is_empty(&self) -> bool {
        self.seen.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{slots, MsgClass};

    fn env(from: PeerId, step: u64, slot: u32, payload: Vec<u8>) -> Envelope {
        Envelope {
            from,
            step,
            slot,
            class: MsgClass::Commitment,
            payload: payload.into(),
            broadcast: true,
            deliver_at: 0,
            signature: None,
        }
    }

    #[test]
    fn consistent_rebroadcast_ok() {
        let mut t = EquivocationTracker::new();
        let e = env(1, 0, slots::GRAD_COMMIT, vec![1, 2]);
        assert!(t.observe(&e).is_none());
        assert!(t.observe(&e).is_none()); // duplicate relay is fine
    }

    #[test]
    fn contradiction_detected() {
        let mut t = EquivocationTracker::new();
        assert!(t.observe(&env(1, 0, slots::GRAD_COMMIT, vec![1])).is_none());
        let ev = t.observe(&env(1, 0, slots::GRAD_COMMIT, vec![2])).unwrap();
        assert_eq!(ev.peer, 1);
        assert_eq!(ev.slot, slots::GRAD_COMMIT);
    }

    #[test]
    fn different_slots_independent() {
        let mut t = EquivocationTracker::new();
        assert!(t.observe(&env(1, 0, slots::sub(slots::GRAD_COMMIT, 0), vec![1])).is_none());
        assert!(t.observe(&env(1, 0, slots::sub(slots::GRAD_COMMIT, 1), vec![2])).is_none());
        assert!(t.observe(&env(1, 1, slots::sub(slots::GRAD_COMMIT, 0), vec![2])).is_none());
        assert!(t.observe(&env(2, 0, slots::sub(slots::GRAD_COMMIT, 0), vec![2])).is_none());
    }

    #[test]
    fn p2p_not_tracked() {
        let mut t = EquivocationTracker::new();
        let mut e = env(1, 0, slots::GRAD_PART, vec![1]);
        e.broadcast = false;
        assert!(t.observe(&e).is_none());
        e.payload = vec![2].into();
        assert!(t.observe(&e).is_none());
        assert!(t.is_empty());
    }

    #[test]
    fn gc_bounds_memory() {
        let mut t = EquivocationTracker::new();
        for step in 0..100 {
            t.observe(&env(1, step, slots::GRAD_COMMIT, vec![1]));
        }
        t.gc(100, 10);
        assert!(t.len() <= 11);
    }

    // -- RelayTracker -------------------------------------------------------

    #[test]
    fn relay_first_then_duplicate() {
        let mut t = RelayTracker::new();
        let e = env(1, 0, slots::GRAD_COMMIT, vec![1, 2]);
        assert_eq!(t.observe(&e), Seen::First);
        assert_eq!(t.observe(&e), Seen::Duplicate);
        assert_eq!(t.observe(&e), Seen::Duplicate);
    }

    #[test]
    fn relay_contradiction_still_relayed_once_per_variant() {
        let mut t = RelayTracker::new();
        assert_eq!(t.observe(&env(1, 0, slots::GRAD_COMMIT, vec![1])), Seen::First);
        match t.observe(&env(1, 0, slots::GRAD_COMMIT, vec![2])) {
            Seen::Contradiction(ev) => {
                assert_eq!(ev.peer, 1);
                assert_eq!(ev.step, 0);
            }
            other => panic!("expected contradiction, got {other:?}"),
        }
        // Each variant relays at most once: re-observing either is a dup.
        assert_eq!(t.observe(&env(1, 0, slots::GRAD_COMMIT, vec![1])), Seen::Duplicate);
        assert_eq!(t.observe(&env(1, 0, slots::GRAD_COMMIT, vec![2])), Seen::Duplicate);
    }

    #[test]
    fn relay_variant_cap_bounds_flooding() {
        let mut t = RelayTracker::new();
        let mut relayed = 0;
        for v in 0u8..50 {
            match t.observe(&env(1, 0, slots::GRAD_COMMIT, vec![v])) {
                Seen::Duplicate => {}
                _ => relayed += 1,
            }
        }
        assert_eq!(relayed, MAX_VARIANTS);
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn relay_p2p_not_tracked() {
        let mut t = RelayTracker::new();
        let mut e = env(1, 0, slots::GRAD_PART, vec![1]);
        e.broadcast = false;
        assert_eq!(t.observe(&e), Seen::First);
        assert_eq!(t.observe(&e), Seen::First);
        assert!(t.is_empty());
    }

    #[test]
    fn relay_origin_premark_drops_echo() {
        let mut t = RelayTracker::new();
        let e = env(3, 5, slots::GRAD_COMMIT, vec![9, 9]);
        let d = sha256(&e.payload);
        assert_eq!(t.observe_digest(3, 5, slots::GRAD_COMMIT, d), Seen::First);
        // The same broadcast echoed back through the overlay: dropped.
        assert_eq!(t.observe(&e), Seen::Duplicate);
    }

    #[test]
    fn relay_gc_bounds_memory() {
        let mut t = RelayTracker::new();
        for step in 0..100 {
            t.observe(&env(1, step, slots::GRAD_COMMIT, vec![1]));
        }
        t.gc(100, 10);
        assert!(t.len() <= 11);
    }

    // -- Overlay ------------------------------------------------------------

    #[test]
    fn overlay_derive_is_a_pure_function_of_roster_and_seed() {
        let live = vec![0usize, 2, 3, 5, 7, 8, 11];
        let a = Overlay::derive(&live, 42, 3, 8);
        let b = Overlay::derive(&live, 42, 3, 8);
        assert_eq!(a, b);
        // Input order must not matter: the roster is a set.
        let mut shuffled = live.clone();
        shuffled.reverse();
        let c = Overlay::derive(&shuffled, 42, 3, 8);
        assert_eq!(a, c);
        // Duplicates must not matter either.
        let mut dup = live.clone();
        dup.extend_from_slice(&live);
        let d = Overlay::derive(&dup, 42, 3, 8);
        assert_eq!(a, d);
        // Different epoch or seed ⇒ (generally) a different graph.
        let e = Overlay::derive(&live, 42, 4, 8);
        let f = Overlay::derive(&live, 43, 3, 8);
        assert!(a != e || a != f);
    }

    #[test]
    fn overlay_degrees_are_logarithmic_and_symmetric() {
        for n in [2usize, 3, 5, 8, 64, 512] {
            let live: Vec<PeerId> = (0..n).collect();
            let fanout = 8;
            let o = Overlay::derive(&live, 7, 0, fanout);
            let log2 = usize::BITS as usize - (n - 1).leading_zeros() as usize;
            for &p in &live {
                let out = o.out_neighbors(p);
                assert!(!out.is_empty(), "n={n} peer {p} has no out-neighbours");
                assert!(out.len() <= fanout.min(log2.max(1)), "n={n} out-degree {}", out.len());
                assert!(!out.contains(&p), "self-loop at {p}");
            }
            // Stride symmetry: total in-degree == total out-degree, and
            // every peer has at least one in-neighbour (someone reaches it).
            let total_out: usize = live.iter().map(|&p| o.out_neighbors(p).len()).sum();
            let total_in: usize = live.iter().map(|&p| o.in_neighbors(p).len()).sum();
            assert_eq!(total_out, total_in);
            for &p in &live {
                assert!(!o.in_neighbors(p).is_empty(), "n={n} peer {p} unreachable");
            }
        }
    }

    /// Flood from every origin over the overlay with one crashed relay:
    /// every live peer must still receive the broadcast (the +1/+2
    /// strides route around any single dead node).
    #[test]
    fn overlay_floods_reach_everyone_with_a_crashed_relay() {
        for n in [3usize, 4, 8, 17, 64] {
            let live: Vec<PeerId> = (0..n).collect();
            let o = Overlay::derive(&live, 13, 1, 8);
            for crashed in 0..n {
                for origin in 0..n {
                    if origin == crashed {
                        continue;
                    }
                    let mut reached = vec![false; n];
                    reached[origin] = true;
                    let mut frontier = vec![origin];
                    while let Some(p) = frontier.pop() {
                        for &q in o.out_neighbors(p) {
                            if q != crashed && !reached[q] {
                                reached[q] = true;
                                frontier.push(q);
                            }
                        }
                    }
                    for p in 0..n {
                        assert!(
                            p == crashed || reached[p],
                            "n={n}: {origin} cannot reach {p} around crashed {crashed}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn overlay_two_peers_link_each_other() {
        let o = Overlay::derive(&[4, 9], 1, 0, 8);
        assert_eq!(o.out_neighbors(4), &[9]);
        assert_eq!(o.out_neighbors(9), &[4]);
    }

    #[test]
    fn overlay_schedule_selects_epoch_by_step() {
        let epochs = vec![
            (0u64, vec![0usize, 1, 2]),
            (3u64, vec![0usize, 1, 2, 3]),
            (6u64, vec![0usize, 1, 3]),
        ];
        let s = OverlaySchedule::derive(&epochs, 7, 8);
        assert_eq!(s.overlay_at(0).members(), &[0, 1, 2]);
        assert_eq!(s.overlay_at(2).members(), &[0, 1, 2]);
        assert_eq!(s.overlay_at(3).members(), &[0, 1, 2, 3]);
        assert_eq!(s.overlay_at(5).members(), &[0, 1, 2, 3]);
        assert_eq!(s.overlay_at(6).members(), &[0, 1, 3]);
        assert_eq!(s.overlay_at(1000).members(), &[0, 1, 3]);
        // Union of dialable relay links across the run.
        let all = s.all_out_neighbors(0);
        for &p in &all {
            assert!(p != 0);
        }
    }
}
