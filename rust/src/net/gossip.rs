//! Broadcast-channel consistency: equivocation detection.
//!
//! The paper (footnote 4) requires that a peer broadcasting two
//! contradicting messages for the same protocol slot be banned, because
//! different honest peers might otherwise act on different values. The
//! transport guarantees every variant is eventually relayed to everyone;
//! this tracker records the first digest seen per (peer, step, slot) and
//! flags any signed contradiction as ban evidence.

use std::collections::HashMap;

use super::{Envelope, PeerId};
use crate::crypto::sha256;

/// Evidence that a peer equivocated: two distinct signed payloads for the
/// same broadcast slot.
#[derive(Clone, Debug)]
pub struct Equivocation {
    pub peer: PeerId,
    pub step: u64,
    pub slot: u32,
}

#[derive(Default)]
pub struct EquivocationTracker {
    seen: HashMap<(PeerId, u64, u32), [u8; 32]>,
}

impl EquivocationTracker {
    pub fn new() -> Self {
        Self::default()
    }

    /// Observe a broadcast envelope. Returns equivocation evidence if this
    /// sender already broadcast different bytes in the same slot.
    pub fn observe(&mut self, env: &Envelope) -> Option<Equivocation> {
        if !env.broadcast {
            return None;
        }
        let digest = sha256(&env.payload);
        let key = (env.from, env.step, env.slot);
        match self.seen.get(&key) {
            None => {
                self.seen.insert(key, digest);
                None
            }
            Some(prev) if *prev == digest => None,
            Some(_) => Some(Equivocation { peer: env.from, step: env.step, slot: env.slot }),
        }
    }

    /// Drop state from steps older than `horizon` (bounded memory).
    pub fn gc(&mut self, current_step: u64, horizon: u64) {
        self.seen
            .retain(|&(_, step, _), _| step + horizon >= current_step);
    }

    pub fn len(&self) -> usize {
        self.seen.len()
    }

    pub fn is_empty(&self) -> bool {
        self.seen.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{slots, MsgClass};

    fn env(from: PeerId, step: u64, slot: u32, payload: Vec<u8>) -> Envelope {
        Envelope {
            from,
            step,
            slot,
            class: MsgClass::Commitment,
            payload: payload.into(),
            broadcast: true,
            deliver_at: 0,
            signature: None,
        }
    }

    #[test]
    fn consistent_rebroadcast_ok() {
        let mut t = EquivocationTracker::new();
        let e = env(1, 0, slots::GRAD_COMMIT, vec![1, 2]);
        assert!(t.observe(&e).is_none());
        assert!(t.observe(&e).is_none()); // duplicate relay is fine
    }

    #[test]
    fn contradiction_detected() {
        let mut t = EquivocationTracker::new();
        assert!(t.observe(&env(1, 0, slots::GRAD_COMMIT, vec![1])).is_none());
        let ev = t.observe(&env(1, 0, slots::GRAD_COMMIT, vec![2])).unwrap();
        assert_eq!(ev.peer, 1);
        assert_eq!(ev.slot, slots::GRAD_COMMIT);
    }

    #[test]
    fn different_slots_independent() {
        let mut t = EquivocationTracker::new();
        assert!(t.observe(&env(1, 0, slots::sub(slots::GRAD_COMMIT, 0), vec![1])).is_none());
        assert!(t.observe(&env(1, 0, slots::sub(slots::GRAD_COMMIT, 1), vec![2])).is_none());
        assert!(t.observe(&env(1, 1, slots::sub(slots::GRAD_COMMIT, 0), vec![2])).is_none());
        assert!(t.observe(&env(2, 0, slots::sub(slots::GRAD_COMMIT, 0), vec![2])).is_none());
    }

    #[test]
    fn p2p_not_tracked() {
        let mut t = EquivocationTracker::new();
        let mut e = env(1, 0, slots::GRAD_PART, vec![1]);
        e.broadcast = false;
        assert!(t.observe(&e).is_none());
        e.payload = vec![2].into();
        assert!(t.observe(&e).is_none());
        assert!(t.is_empty());
    }

    #[test]
    fn gc_bounds_memory() {
        let mut t = EquivocationTracker::new();
        for step in 0..100 {
            t.observe(&env(1, step, slots::GRAD_COMMIT, vec![1]));
        }
        t.gc(100, 10);
        assert!(t.len() <= 11);
    }
}
