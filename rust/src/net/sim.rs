//! Deterministic network-condition simulation: the `SimNet` transport.
//!
//! `SimNet` wraps the perfect in-process fabric (`local::PeerNet`) with a
//! seeded per-link network model ([`NetworkProfile`]). Every fault
//! decision — transmission loss, retransmit count, tail-latency delay,
//! straggler/partition membership — is a pure hash of
//! `(seed, from, to, step, slot)`, so a run is reproducible bit-for-bit
//! for a given seed regardless of worker count or wall-clock timing.
//!
//! ## What the model does (and deliberately does not) fault
//!
//! - **P2P payload traffic** (gradient parts, aggregated parts) suffers
//!   per-link transmission loss with bounded retransmits and per-message
//!   tail latency. A message whose retransmits are exhausted is lost for
//!   good; a late message is stamped with a `deliver_at` phase-clock gate
//!   and arrives after its collect window — the receiver observes a
//!   timeout and the protocol's ELIMINATE machinery takes over, exactly
//!   the straggler-handling path a perfect fabric never exercises.
//! - **Broadcast control traffic** stays reliable and on time. The paper
//!   (footnote 4) *assumes* an eventually-consistent broadcast channel —
//!   GossipSub's redundant relays — and every ban decision is a
//!   deterministic function of broadcast data; faulting broadcasts
//!   per-link would violate the assumption the protocol is built on, not
//!   test its robustness. The one exception is a **blackout**: a
//!   partitioned peer's broadcasts never enter the mesh at all, which the
//!   cluster converts into a cheap `Proven` MPRNG-abort ban the same
//!   step.
//! - **Self loopback is exempt**: a peer always sees its own broadcasts
//!   (loopback never crosses the network).
//! - **Membership state transfer is exempt**: the sponsor's JOIN
//!   snapshot (the one p2p message on a `JOIN` slot) is delivered
//!   reliably and on time, by the same control-plane assumption that
//!   keeps broadcasts reliable — admission is schedule-driven, so a
//!   faulted snapshot would orphan a peer every incumbent has already
//!   admitted rather than exercise any protocol defense. All of a
//!   joiner's *ordinary* traffic is faulted normally from its boundary
//!   on (its phase clock is synchronized to the cluster's at install).
//! - **Peer 0 is exempt from hash-drawn straggler/partition membership**
//!   (it is the harness's metrics recorder, like the "peer 0 stays
//!   honest" rule for attacks). Its links still carry loss and latency,
//!   and it still pays the mutual-elimination tax when it observes a
//!   miss — explicit `*_peers` overrides can target any peer.
//!
//! Latency is measured in *protocol phases* (the logical clock advanced
//! once per stage entry), not wall time: sub-phase latency is absorbed by
//! the stage barrier, so the model surfaces exactly the tail that
//! matters — deliveries that land after their collect window.

use std::sync::{Arc, Mutex};

use super::local::{build_cluster, PeerNet};
use super::{slots, ClusterInfo, Envelope, MsgClass, PeerId, RecvError, RecvMode, Transport};
use crate::util::json::Json;
use crate::util::rng::splitmix64;
use std::time::Duration;

/// Declarative network-condition model, the `network` knob of a run.
/// Probabilities are per message (or per transmission attempt for
/// `drop`); latency is in protocol phases. See the module docs for what
/// is faulted.
#[derive(Clone, Debug, PartialEq)]
pub struct NetworkProfile {
    /// Preset name (reports / CSV label): perfect, lossy, partitioned,
    /// straggler, or custom.
    pub name: String,
    /// Per-transmission loss probability on p2p links (retransmitted).
    pub drop: f64,
    /// Retransmits before a p2p message is lost for good.
    pub max_retries: u32,
    /// Per-message probability that a p2p delivery lands late.
    pub late_p: f64,
    /// How many phases past the send a late delivery lands (≥ 2 misses
    /// the immediate collect; the stage gap between send and collect
    /// absorbs a delay of 1).
    pub late_phases: u64,
    /// Fraction of peers (hash-drawn, peer 0 exempt) with degraded
    /// uplinks. Ignored when `straggler_peers` is non-empty.
    pub straggler_frac: f64,
    /// Per-message probability that a straggler's p2p send is late.
    pub straggle_p: f64,
    /// Explicit straggler set (overrides `straggler_frac`).
    pub straggler_peers: Vec<PeerId>,
    /// Fraction of peers (hash-drawn, peer 0 exempt) blacked out during
    /// the partition window. Ignored when `partition_peers` is non-empty.
    pub partition_frac: f64,
    /// Blackout window `[partition_start, partition_end)` in training
    /// steps: all outgoing traffic of partitioned peers is dropped.
    pub partition_start: u64,
    pub partition_end: u64,
    /// Explicit blackout set (overrides `partition_frac`).
    pub partition_peers: Vec<PeerId>,
    /// Directed p2p links that are dead outright (test hook and
    /// broken-wire scenarios): every send on them is lost.
    pub faulty_links: Vec<(PeerId, PeerId)>,
    /// Extra entropy mixed into the run seed (profiles with the same
    /// shape can still draw different fault schedules).
    pub seed: u64,
}

impl Default for NetworkProfile {
    fn default() -> Self {
        NetworkProfile {
            name: "perfect".to_string(),
            drop: 0.0,
            max_retries: 3,
            late_p: 0.0,
            late_phases: 3,
            straggler_frac: 0.0,
            straggle_p: 0.15,
            straggler_peers: vec![],
            partition_frac: 0.0,
            partition_start: 2,
            partition_end: 4,
            partition_peers: vec![],
            faulty_links: vec![],
            seed: 0,
        }
    }
}

impl NetworkProfile {
    /// The zero-fault profile (identical behaviour to the raw fabric).
    pub fn perfect() -> NetworkProfile {
        NetworkProfile::default()
    }

    /// True when no fault can ever fire — the builder then uses the raw
    /// `PeerNet` fabric, keeping default runs bit-identical to the
    /// pre-Transport-seam path.
    pub fn is_perfect(&self) -> bool {
        self.drop == 0.0
            && self.late_p == 0.0
            && (self.straggle_p == 0.0
                || (self.straggler_frac == 0.0 && self.straggler_peers.is_empty()))
            && self.partition_frac == 0.0
            && self.partition_peers.is_empty()
            && self.faulty_links.is_empty()
    }

    /// Parse a preset name with an optional parameter:
    /// `perfect`, `lossy[:drop]`, `partitioned[:frac]`, `straggler[:frac]`.
    pub fn from_name(s: &str) -> Option<NetworkProfile> {
        let (name, arg) = match s.split_once(':') {
            Some((n, a)) => (n, Some(a)),
            None => (s, None),
        };
        let arg_f64 = |default: f64| -> Option<f64> {
            match arg {
                Some(a) => a.parse::<f64>().ok().filter(|v| (0.0..1.0).contains(v)),
                None => Some(default),
            }
        };
        let mut p = NetworkProfile::default();
        match name {
            "perfect" => {
                if arg.is_some() {
                    return None; // no parameter accepted
                }
                Some(p)
            }
            "lossy" => {
                p.name = "lossy".to_string();
                p.drop = arg_f64(0.05)?;
                p.late_p = 2e-4;
                Some(p)
            }
            "partitioned" => {
                p.name = "partitioned".to_string();
                p.partition_frac = arg_f64(0.125)?;
                Some(p)
            }
            "straggler" => {
                p.name = "straggler".to_string();
                p.straggler_frac = arg_f64(0.125)?;
                Some(p)
            }
            _ => None,
        }
    }

    /// Parse from JSON: either a preset-name string (`"lossy:0.05"`) or
    /// an object starting from the named preset (default perfect) with
    /// field overrides. Unknown keys and wrong-typed values are hard
    /// errors, matching the scenario-spec parser's strictness.
    pub fn from_json(j: &Json) -> Result<NetworkProfile, String> {
        if let Some(s) = j.as_str() {
            return NetworkProfile::from_name(s)
                .ok_or_else(|| format!("unknown network profile '{s}'"));
        }
        let obj = j.as_obj().ok_or("network must be a profile name or an object")?;
        const KNOWN: [&str; 14] = [
            "name",
            "drop",
            "max_retries",
            "late_p",
            "late_phases",
            "straggler_frac",
            "straggle_p",
            "straggler_peers",
            "partition_frac",
            "partition_start",
            "partition_end",
            "partition_peers",
            "faulty_links",
            "seed",
        ];
        for key in obj.keys() {
            if !KNOWN.contains(&key.as_str()) {
                return Err(format!("unknown network profile key '{key}'"));
            }
        }
        let mut p = match j.get("name").map(|v| v.as_str().ok_or("network.name must be a string")) {
            Some(Ok(name)) => NetworkProfile::from_name(name)
                .ok_or_else(|| format!("unknown network profile '{name}'"))?,
            Some(Err(e)) => return Err(e.to_string()),
            None => NetworkProfile::default(),
        };
        let prob = |v: &Json, key: &str| -> Result<f64, String> {
            let f = v.as_f64().ok_or_else(|| format!("network.{key} must be a number"))?;
            if !(0.0..1.0).contains(&f) {
                return Err(format!("network.{key} {f} outside [0, 1)"));
            }
            Ok(f)
        };
        if let Some(v) = j.get("drop") {
            p.drop = prob(v, "drop")?;
        }
        if let Some(v) = j.get("max_retries") {
            p.max_retries =
                v.as_u64().ok_or("network.max_retries must be an integer")? as u32;
        }
        if let Some(v) = j.get("late_p") {
            p.late_p = prob(v, "late_p")?;
        }
        if let Some(v) = j.get("late_phases") {
            p.late_phases = v.as_u64().ok_or("network.late_phases must be an integer")?;
        }
        if let Some(v) = j.get("straggler_frac") {
            p.straggler_frac = prob(v, "straggler_frac")?;
        }
        if let Some(v) = j.get("straggle_p") {
            p.straggle_p = prob(v, "straggle_p")?;
        }
        if let Some(v) = j.get("partition_frac") {
            p.partition_frac = prob(v, "partition_frac")?;
        }
        if let Some(v) = j.get("partition_start") {
            p.partition_start =
                v.as_u64().ok_or("network.partition_start must be an integer")?;
        }
        if let Some(v) = j.get("partition_end") {
            p.partition_end = v.as_u64().ok_or("network.partition_end must be an integer")?;
        }
        if let Some(v) = j.get("seed") {
            p.seed = v.as_u64().ok_or("network.seed must be an integer")?;
        }
        let peer_list = |v: &Json, key: &str| -> Result<Vec<PeerId>, String> {
            let arr = v.as_arr().ok_or_else(|| format!("network.{key} must be an array"))?;
            let parsed: Vec<PeerId> = arr.iter().filter_map(|x| x.as_usize()).collect();
            if parsed.len() != arr.len() {
                return Err(format!("network.{key} must contain integers"));
            }
            Ok(parsed)
        };
        if let Some(v) = j.get("straggler_peers") {
            p.straggler_peers = peer_list(v, "straggler_peers")?;
        }
        if let Some(v) = j.get("partition_peers") {
            p.partition_peers = peer_list(v, "partition_peers")?;
        }
        if let Some(v) = j.get("faulty_links") {
            let arr = v.as_arr().ok_or("network.faulty_links must be an array")?;
            let mut links = Vec::with_capacity(arr.len());
            for pair in arr {
                let ends = pair.as_arr().map(|p| {
                    (p.first().and_then(|x| x.as_usize()), p.get(1).and_then(|x| x.as_usize()))
                });
                match ends {
                    Some((Some(a), Some(b))) => links.push((a, b)),
                    _ => return Err("network.faulty_links entries must be [from, to]".into()),
                }
            }
            p.faulty_links = links;
        }
        Ok(p)
    }
}

/// Per-peer fault/bandwidth counters (sender-attributed).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct PeerFaults {
    /// Logical messages handed to the transport (p2p sends + broadcasts).
    pub sent_msgs: u64,
    /// Messages lost for good (exhausted retransmits, dead link, blackout).
    pub dropped_msgs: u64,
    /// Messages delivered after their collect window.
    pub late_msgs: u64,
    /// Extra transmission attempts beyond the first.
    pub retransmits: u64,
    /// Bytes spent on those extra attempts (the bandwidth tax of loss).
    pub retransmit_bytes: u64,
}

/// Shared fault accounting for a simulated cluster. Counters are
/// commutative sums, so totals are deterministic under any worker
/// interleaving.
#[derive(Debug, Default)]
pub struct FaultStats {
    peers: Mutex<Vec<PeerFaults>>,
}

impl FaultStats {
    pub fn new(n: usize) -> FaultStats {
        FaultStats { peers: Mutex::new(vec![PeerFaults::default(); n]) }
    }

    fn record(&self, from: PeerId, f: impl FnOnce(&mut PeerFaults)) {
        let mut g = self.peers.lock().unwrap();
        f(&mut g[from]);
    }

    pub fn snapshot(&self) -> Vec<PeerFaults> {
        self.peers.lock().unwrap().clone()
    }

    /// Cluster-wide totals (the CSV columns of the scenario matrix).
    pub fn totals(&self) -> PeerFaults {
        let g = self.peers.lock().unwrap();
        let mut t = PeerFaults::default();
        for p in g.iter() {
            t.sent_msgs += p.sent_msgs;
            t.dropped_msgs += p.dropped_msgs;
            t.late_msgs += p.late_msgs;
            t.retransmits += p.retransmits;
            t.retransmit_bytes += p.retransmit_bytes;
        }
        t
    }
}

/// The fate of one logical message, decided at send time.
enum Fate {
    /// `transmissions` attempts were made and the last one arrives; a
    /// non-zero `deliver_at` gates delivery on the receiver's clock.
    Deliver { deliver_at: u64, transmissions: u32 },
    /// Lost for good after `transmissions` attempts (0 = never sent,
    /// e.g. a blacked-out NIC).
    Drop { transmissions: u32 },
}

/// Immutable fault model shared by every `SimNet` endpoint of a cluster.
pub struct SimModel {
    profile: NetworkProfile,
    seed: u64,
    stragglers: Vec<bool>,
    partitioned: Vec<bool>,
    pub faults: Arc<FaultStats>,
}

// Domain-separation tags for the fate hash.
const TAG_LOSS: u64 = 0x1001;
const TAG_LATE: u64 = 0x1002;
const TAG_STRAGGLE: u64 = 0x1003;
const TAG_MEMBER_STRAGGLER: u64 = 0x1004;
const TAG_MEMBER_PARTITION: u64 = 0x1005;

impl SimModel {
    pub fn new(profile: NetworkProfile, run_seed: u64, n: usize) -> SimModel {
        let mut s = run_seed ^ profile.seed.rotate_left(17) ^ 0x5EED_0000_0000_0001;
        let seed = splitmix64(&mut s);
        let mut model = SimModel {
            profile,
            seed,
            stragglers: vec![false; n],
            partitioned: vec![false; n],
            faults: Arc::new(FaultStats::new(n)),
        };
        let explicit_stragglers = model.profile.straggler_peers.clone();
        let explicit_partition = model.profile.partition_peers.clone();
        if explicit_stragglers.is_empty() {
            let frac = model.profile.straggler_frac;
            for p in 1..n {
                // Peer 0 exempt: it is the metrics recorder (module docs).
                let u = model.unit(TAG_MEMBER_STRAGGLER, p as u64, 0, 0, 0);
                model.stragglers[p] = u < frac;
            }
        } else {
            for p in explicit_stragglers {
                // A typo'd peer id must not silently run a fault-free
                // experiment under a faulty profile's name.
                assert!(p < n, "network profile straggler peer {p} outside cluster of {n}");
                model.stragglers[p] = true;
            }
        }
        if explicit_partition.is_empty() {
            let frac = model.profile.partition_frac;
            for p in 1..n {
                let u = model.unit(TAG_MEMBER_PARTITION, p as u64, 0, 0, 0);
                model.partitioned[p] = u < frac;
            }
        } else {
            for p in explicit_partition {
                assert!(p < n, "network profile partition peer {p} outside cluster of {n}");
                model.partitioned[p] = true;
            }
        }
        model
    }

    /// Stateless fate hash: a pure function of the model seed and the
    /// message key, so fates never depend on execution order.
    fn hash(&self, tag: u64, a: u64, b: u64, c: u64, d: u64) -> u64 {
        let mut s = self.seed ^ tag.wrapping_mul(0xD6E8_FEB8_6659_FD93);
        for v in [a, b, c, d] {
            s ^= v.wrapping_mul(0x9E37_79B9_7F4A_7C15);
            s = splitmix64(&mut s);
        }
        s
    }

    /// Uniform sample in [0, 1) from the fate hash.
    fn unit(&self, tag: u64, a: u64, b: u64, c: u64, d: u64) -> f64 {
        (self.hash(tag, a, b, c, d) >> 11) as f64 / (1u64 << 53) as f64
    }

    fn blacked_out(&self, peer: PeerId, step: u64) -> bool {
        self.partitioned[peer]
            && step >= self.profile.partition_start
            && step < self.profile.partition_end
    }

    /// Fate of one p2p transmission `from → to` at `(step, slot)`.
    fn p2p_fate(&self, from: PeerId, to: PeerId, step: u64, slot: u32, clock: u64) -> Fate {
        if self.blacked_out(from, step) {
            return Fate::Drop { transmissions: 0 };
        }
        if self.profile.faulty_links.contains(&(from, to)) {
            return Fate::Drop { transmissions: 1 };
        }
        // Transmission loss with bounded retransmits: each attempt has an
        // independent per-link loss draw; exhausting them loses the
        // message for good.
        let mut failures = 0u32;
        while failures <= self.profile.max_retries {
            let u = self.unit(
                TAG_LOSS ^ ((failures as u64) << 32),
                from as u64,
                to as u64,
                step,
                slot as u64,
            );
            if u >= self.profile.drop {
                break;
            }
            failures += 1;
        }
        if failures > self.profile.max_retries {
            return Fate::Drop { transmissions: failures };
        }
        // Tail latency: base per-message probability, plus the degraded
        // uplink of a straggler sender.
        let mut late = self.profile.late_p > 0.0
            && self.unit(TAG_LATE, from as u64, to as u64, step, slot as u64)
                < self.profile.late_p;
        if !late && self.stragglers[from] {
            late = self.profile.straggle_p > 0.0
                && self.unit(TAG_STRAGGLE, from as u64, to as u64, step, slot as u64)
                    < self.profile.straggle_p;
        }
        let deliver_at = if late { clock + self.profile.late_phases } else { 0 };
        Fate::Deliver { deliver_at, transmissions: failures + 1 }
    }

    /// Fate of a broadcast: reliable and on time (the paper's
    /// eventual-consistency assumption) unless the sender is blacked out.
    fn broadcast_fate(&self, from: PeerId, step: u64) -> Fate {
        if self.blacked_out(from, step) {
            Fate::Drop { transmissions: 0 }
        } else {
            Fate::Deliver { deliver_at: 0, transmissions: 1 }
        }
    }
}

/// Transport backend that injects deterministic network faults between
/// the protocol and the in-process fabric. Receives delegate to the
/// inner `PeerNet`; sends consult the shared [`SimModel`].
pub struct SimNet {
    inner: PeerNet,
    model: Arc<SimModel>,
}

impl SimNet {
    pub fn new(inner: PeerNet, model: Arc<SimModel>) -> SimNet {
        SimNet { inner, model }
    }
}

impl Transport for SimNet {
    fn id(&self) -> PeerId {
        self.inner.id
    }

    fn info(&self) -> &Arc<ClusterInfo> {
        &self.inner.info
    }

    fn set_timeout(&mut self, timeout: Duration) {
        self.inner.timeout = timeout;
    }

    fn set_recv_mode(&mut self, mode: RecvMode) {
        self.inner.recv_mode = mode;
    }

    fn tick(&mut self) {
        self.inner.advance_clock();
    }

    fn clock(&self) -> u64 {
        self.inner.now()
    }

    fn set_min_step(&mut self, step: u64) {
        Transport::set_min_step(&mut self.inner, step);
    }

    fn send(&mut self, to: PeerId, step: u64, slot: u32, class: MsgClass, payload: Vec<u8>) {
        let me = self.inner.id;
        if to == me {
            // Loopback never crosses the network.
            PeerNet::send(&self.inner, to, step, slot, class, payload);
            return;
        }
        if slots::tag(slot) == slots::JOIN {
            // Membership state transfer (the sponsor's JOIN snapshot) is
            // control-plane traffic: reliable and on time by the same
            // eventual-consistency assumption that keeps broadcasts
            // reliable (module docs). Faulting it would not test the
            // protocol's robustness — it would desynchronize admission
            // itself (incumbents admit by schedule; a dropped snapshot
            // would orphan an already-admitted joiner).
            let bytes = payload.len();
            self.inner.info.stats.record_p2p(me, class, bytes);
            self.model.faults.record(me, |f| f.sent_msgs += 1);
            let env = self.inner.make_envelope(step, slot, class, payload, false);
            self.inner.push_to(to, env);
            return;
        }
        let bytes = payload.len();
        let stats = &self.inner.info.stats;
        let faults = &self.model.faults;
        // One FaultStats lock per message: the counters are folded into a
        // single record() call so pool workers don't serialize twice on
        // the shared mutex in the per-message hot path.
        match self.model.p2p_fate(me, to, step, slot, self.inner.now()) {
            Fate::Drop { transmissions } => {
                for _ in 0..transmissions {
                    stats.record_p2p(me, class, bytes);
                }
                faults.record(me, |f| {
                    f.sent_msgs += 1;
                    f.dropped_msgs += 1;
                    f.retransmits += transmissions.saturating_sub(1) as u64;
                    f.retransmit_bytes += transmissions.saturating_sub(1) as u64 * bytes as u64;
                });
            }
            Fate::Deliver { deliver_at, transmissions } => {
                for _ in 0..transmissions {
                    stats.record_p2p(me, class, bytes);
                }
                faults.record(me, |f| {
                    f.sent_msgs += 1;
                    f.late_msgs += u64::from(deliver_at > 0);
                    f.retransmits += (transmissions - 1) as u64;
                    f.retransmit_bytes += (transmissions - 1) as u64 * bytes as u64;
                });
                let mut env = self.inner.make_envelope(step, slot, class, payload, false);
                env.deliver_at = deliver_at;
                self.inner.push_to(to, env);
            }
        }
    }

    fn broadcast(&mut self, step: u64, slot: u32, class: MsgClass, payload: Vec<u8>) {
        let me = self.inner.id;
        let bytes = payload.len();
        let env = self.inner.make_envelope(step, slot, class, payload, true);
        match self.model.broadcast_fate(me, step) {
            Fate::Drop { .. } => {
                // Blacked out: nothing enters the gossip mesh, but the
                // sender still observes its own broadcast via loopback.
                self.model.faults.record(me, |f| {
                    f.sent_msgs += 1;
                    f.dropped_msgs += 1;
                });
                self.inner.push_to(me, env);
            }
            Fate::Deliver { .. } => {
                self.model.faults.record(me, |f| f.sent_msgs += 1);
                self.inner.info.stats.record_broadcast(me, class, bytes);
                for p in 0..self.inner.info.n_peers {
                    self.inner.push_to(p, env.clone());
                }
            }
        }
    }

    fn broadcast_split(
        &mut self,
        step: u64,
        slot: u32,
        class: MsgClass,
        variants: Vec<(PeerId, Vec<u8>)>,
    ) {
        // Same distinct-variant relay semantics as the perfect fabric;
        // the blackout fate is uniform per (from, step), so all variants
        // of one equivocation share it.
        for payload in super::local::distinct_variants(&variants) {
            self.broadcast(step, slot, class, payload);
        }
    }

    fn recv_keyed(
        &mut self,
        step: u64,
        slot: u32,
        pred: &dyn Fn(&Envelope) -> bool,
    ) -> Result<Envelope, RecvError> {
        Transport::recv_keyed(&mut self.inner, step, slot, pred)
    }

    fn drain_match(&mut self, pred: &dyn Fn(&Envelope) -> bool) -> Vec<Envelope> {
        Transport::drain_match(&mut self.inner, pred)
    }

    fn fault_handle(&self) -> Option<Arc<FaultStats>> {
        Some(self.model.faults.clone())
    }
}

/// Build a cluster of transport endpoints for the given network profile:
/// the raw perfect fabric when no fault can fire (bit-identical to the
/// pre-Transport-seam path), `SimNet` around a shared fault model
/// otherwise. `run_seed` feeds the fate hash together with
/// `profile.seed`.
pub fn build_transports(
    n: usize,
    key_seed: u64,
    verify_signatures: bool,
    profile: &NetworkProfile,
    run_seed: u64,
) -> Vec<Box<dyn Transport>> {
    let cluster = build_cluster(n, key_seed, verify_signatures);
    if profile.is_perfect() {
        return cluster.into_iter().map(|p| Box::new(p) as Box<dyn Transport>).collect();
    }
    let model = Arc::new(SimModel::new(profile.clone(), run_seed, n));
    cluster
        .into_iter()
        .map(|p| Box::new(SimNet::new(p, model.clone())) as Box<dyn Transport>)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::slots;

    #[test]
    fn preset_names_parse() {
        assert!(NetworkProfile::from_name("perfect").unwrap().is_perfect());
        let lossy = NetworkProfile::from_name("lossy").unwrap();
        assert_eq!(lossy.drop, 0.05);
        assert!(!lossy.is_perfect());
        let lossy2 = NetworkProfile::from_name("lossy:0.2").unwrap();
        assert_eq!(lossy2.drop, 0.2);
        let part = NetworkProfile::from_name("partitioned:0.25").unwrap();
        assert_eq!(part.partition_frac, 0.25);
        assert!(!part.is_perfect());
        let strag = NetworkProfile::from_name("straggler").unwrap();
        assert_eq!(strag.straggler_frac, 0.125);
        assert!(!strag.is_perfect());
        assert!(NetworkProfile::from_name("bogus").is_none());
        assert!(NetworkProfile::from_name("lossy:1.5").is_none());
        assert!(NetworkProfile::from_name("perfect:0.1").is_none());
    }

    #[test]
    fn json_profiles_parse_strictly() {
        let p = NetworkProfile::from_json(&Json::parse("\"lossy:0.1\"").unwrap()).unwrap();
        assert_eq!(p.drop, 0.1);
        let p = NetworkProfile::from_json(
            &Json::parse(r#"{"name": "lossy", "drop": 0.02, "late_p": 0.001, "seed": 7}"#)
                .unwrap(),
        )
        .unwrap();
        assert_eq!(p.drop, 0.02);
        assert_eq!(p.late_p, 0.001);
        assert_eq!(p.seed, 7);
        let p = NetworkProfile::from_json(
            &Json::parse(r#"{"faulty_links": [[3, 5]], "straggler_peers": [2]}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(p.faulty_links, vec![(3, 5)]);
        assert_eq!(p.straggler_peers, vec![2]);
        assert!(!p.is_perfect());
        // Unknown keys / malformed values are hard errors.
        assert!(NetworkProfile::from_json(&Json::parse(r#"{"drp": 0.1}"#).unwrap()).is_err());
        assert!(NetworkProfile::from_json(&Json::parse(r#"{"drop": 1.5}"#).unwrap()).is_err());
        assert!(NetworkProfile::from_json(&Json::parse(r#"{"name": "nope"}"#).unwrap()).is_err());
        assert!(
            NetworkProfile::from_json(&Json::parse(r#"{"faulty_links": [[1]]}"#).unwrap()).is_err()
        );
    }

    #[test]
    fn fates_are_deterministic_and_respect_extremes() {
        let mut p = NetworkProfile::from_name("lossy").unwrap();
        p.drop = 1.0 - 1e-9; // every attempt fails
        let m = SimModel::new(p, 42, 4);
        match m.p2p_fate(1, 2, 0, slots::GRAD_PART, 0) {
            Fate::Drop { transmissions } => assert_eq!(transmissions, 4), // 1 + 3 retries
            Fate::Deliver { .. } => panic!("drop=1 must drop"),
        }
        let mut p = NetworkProfile::perfect();
        p.late_p = 1.0 - 1e-9;
        p.late_phases = 5;
        let m = SimModel::new(p, 42, 4);
        match m.p2p_fate(1, 2, 3, slots::GRAD_PART, 10) {
            Fate::Deliver { deliver_at, transmissions } => {
                assert_eq!(deliver_at, 15);
                assert_eq!(transmissions, 1);
            }
            Fate::Drop { .. } => panic!("late_p alone must not drop"),
        }
        // Same key ⇒ same fate; different key ⇒ independent draw.
        let p = NetworkProfile::from_name("lossy:0.5").unwrap();
        let m = SimModel::new(p.clone(), 9, 8);
        let a1 = matches!(m.p2p_fate(1, 2, 0, 7, 0), Fate::Drop { .. });
        let a2 = matches!(m.p2p_fate(1, 2, 0, 7, 0), Fate::Drop { .. });
        assert_eq!(a1, a2);
        let m2 = SimModel::new(p, 9, 8);
        let b1 = matches!(m2.p2p_fate(1, 2, 0, 7, 0), Fate::Drop { .. });
        assert_eq!(a1, b1, "same seed ⇒ same fate schedule");
    }

    #[test]
    fn hash_membership_never_selects_peer_zero() {
        let mut p = NetworkProfile::from_name("straggler:0.49").unwrap();
        p.partition_frac = 0.49;
        let m = SimModel::new(p, 123, 64);
        assert!(!m.stragglers[0]);
        assert!(!m.partitioned[0]);
        assert!(m.stragglers.iter().any(|&s| s), "frac 0.49 of 64 should pick someone");
        assert!(m.partitioned.iter().any(|&s| s));
    }

    #[test]
    fn dead_link_drops_p2p_but_broadcasts_still_deliver() {
        let mut profile = NetworkProfile::perfect();
        profile.name = "deadlink".to_string();
        profile.faulty_links = vec![(1, 0)];
        let mut cluster = build_transports(2, 700, true, &profile, 5);
        let mut p1 = cluster.pop().unwrap();
        let mut p0 = cluster.pop().unwrap();
        p0.set_recv_mode(RecvMode::Drain);
        p1.send(0, 0, slots::GRAD_PART, MsgClass::GradientPart, vec![1]);
        assert!(p0.recv_keyed(0, slots::GRAD_PART, &|_| true).is_err(), "dead link delivered");
        p1.broadcast(0, slots::GRAD_COMMIT, MsgClass::Commitment, vec![2]);
        let env = p0.recv_keyed(0, slots::GRAD_COMMIT, &|_| true).unwrap();
        assert_eq!(env.payload.to_vec(), vec![2]);
        let totals = p1.fault_handle().unwrap().totals();
        assert_eq!(totals.dropped_msgs, 1);
        assert_eq!(totals.sent_msgs, 2);
    }

    #[test]
    fn blackout_silences_broadcasts_except_loopback() {
        let mut profile = NetworkProfile::perfect();
        profile.name = "blackout".to_string();
        profile.partition_peers = vec![1];
        profile.partition_start = 0;
        profile.partition_end = 2;
        let mut cluster = build_transports(2, 800, true, &profile, 5);
        let mut p1 = cluster.pop().unwrap();
        let mut p0 = cluster.pop().unwrap();
        p0.set_recv_mode(RecvMode::Drain);
        p1.set_recv_mode(RecvMode::Drain);
        p1.broadcast(0, slots::GRAD_COMMIT, MsgClass::Commitment, vec![9]);
        assert!(p0.recv_keyed(0, slots::GRAD_COMMIT, &|_| true).is_err());
        // The sender still sees its own broadcast (self bookkeeping).
        let own = p1.recv_keyed(0, slots::GRAD_COMMIT, &|_| true).unwrap();
        assert_eq!(own.payload.to_vec(), vec![9]);
        // After the window the peer is reachable again.
        p1.broadcast(2, slots::GRAD_COMMIT, MsgClass::Commitment, vec![8]);
        let env = p0.recv_keyed(2, slots::GRAD_COMMIT, &|_| true).unwrap();
        assert_eq!(env.payload.to_vec(), vec![8]);
    }

    #[test]
    fn retransmit_bytes_are_accounted() {
        // drop ≈ 1 for the first attempts is impossible to pin without
        // fixed hashes, so use drop = 0 and a straggler to check the late
        // path, then a dead link for the drop path — the retransmit
        // accounting itself is covered by fates_are_deterministic.
        let mut profile = NetworkProfile::perfect();
        profile.name = "straggle-all".to_string();
        profile.straggler_peers = vec![1];
        profile.straggle_p = 1.0 - 1e-9;
        profile.late_phases = 2;
        let mut cluster = build_transports(2, 900, true, &profile, 5);
        let mut p1 = cluster.pop().unwrap();
        let mut p0 = cluster.pop().unwrap();
        p0.set_recv_mode(RecvMode::Drain);
        p1.send(0, 0, slots::GRAD_PART, MsgClass::GradientPart, vec![1, 2, 3]);
        // Late: parked behind the phase gate until p0's clock reaches it.
        assert!(p0.recv_keyed(0, slots::GRAD_PART, &|_| true).is_err());
        p0.tick();
        p0.tick();
        let env = p0.recv_keyed(0, slots::GRAD_PART, &|_| true).unwrap();
        assert_eq!(env.payload.to_vec(), vec![1, 2, 3]);
        let totals = p1.fault_handle().unwrap().totals();
        assert_eq!(totals.late_msgs, 1);
        assert_eq!(totals.dropped_msgs, 0);
    }
}
