//! The message-authentication seam: how an endpoint credentials its
//! outgoing envelopes and authenticates incoming ones.
//!
//! Mirrors the [`Transport`](super::Transport) pattern: the delivery
//! machinery ([`local::Inbox`](super::local::Inbox)) is written against
//! [`MessageAuth`] only, so an authentication-policy swap — sign
//! everything, sign nothing, or the socket transport's session-MAC mode
//! where only adjudication-bound slots carry signatures — never touches
//! receive-path code, and raw `sign()`/`verify()` calls stop being
//! scattered across the transports.
//!
//! Three policies:
//!
//! - [`SchnorrAuth`] — every envelope is signed by its sender and
//!   verified against the roster key (the paper's default model). Batch
//!   authentication uses the random-linear-combination Schnorr batch
//!   check ([`crypto::batch_verify`]): one combined exponentiation
//!   replaces per-envelope ones, and on failure the batch falls back to
//!   per-envelope verification so the forged envelope — and only it — is
//!   attributed and dropped.
//! - [`SessionAuth`] — the socket transport's negotiated session-MAC
//!   mode: the per-link frame MAC authenticates the *stream*, so bulk
//!   payloads (gradient and aggregate parts) travel unsigned; envelopes
//!   whose slot can end up in an adjudication transcript (commitments,
//!   votes, accusations, membership) still carry real signatures,
//!   because a MAC only convinces the link endpoint, never a third peer.
//! - [`NoAuth`] — the `verify_signatures = false` benchmarking mode:
//!   nothing is credentialed, everything is accepted.

use super::{slots, Envelope};
use crate::crypto::{batch_verify, Mont, PublicKey, SecretKey, Signature};

/// Slots whose envelopes may be forwarded to third parties as evidence
/// (commitments, votes, accusations, membership changes) and therefore
/// must carry a transferable credential — a signature — even on links
/// whose stream is already MAC-authenticated. The O(d) bulk payloads
/// (`GRAD_PART`, `AGG_PART`) are exempt: their bytes are bound by the
/// signed commitments, so a tampered part is caught by the hash check
/// and attributed through the commitment, never through the part itself.
pub fn requires_signature(slot: u32) -> bool {
    !matches!(slots::tag(slot), slots::GRAD_PART | slots::AGG_PART)
}

/// How an endpoint credentials outgoing envelopes and authenticates
/// incoming ones. Implementations are per-endpoint, not per-link: the
/// link-level stream MAC of the socket transport lives in the frame
/// codec; this seam decides what the *envelope* must carry on top.
pub trait MessageAuth: Send + Sync {
    /// Attach whatever credential this policy requires (called once by
    /// the sender, before the envelope is cloned per recipient).
    fn seal(&self, env: &mut Envelope);

    /// Authenticate one envelope (blocking receive path).
    fn verify(&self, env: &Envelope) -> bool;

    /// Authenticate a batch of queued envelopes (drain-mode refills,
    /// where the stage barrier has already queued everything a collect
    /// will ask for). Returns one verdict per envelope, in order.
    fn verify_batch(&self, envs: &[Envelope]) -> Vec<bool> {
        envs.iter().map(|e| self.verify(e)).collect()
    }
}

/// Sign-everything / verify-everything (the paper's default model).
pub struct SchnorrAuth {
    mont: Mont,
    /// The endpoint's signing key; `None` for verify-only endpoints.
    secret: Option<SecretKey>,
    public_keys: Vec<PublicKey>,
}

impl SchnorrAuth {
    pub fn new(mont: Mont, secret: Option<SecretKey>, public_keys: Vec<PublicKey>) -> SchnorrAuth {
        SchnorrAuth { mont, secret, public_keys }
    }

    fn key_of(&self, env: &Envelope) -> Option<&PublicKey> {
        self.public_keys.get(env.from)
    }
}

impl MessageAuth for SchnorrAuth {
    fn seal(&self, env: &mut Envelope) {
        if let Some(sk) = &self.secret {
            env.sign_with(&self.mont, sk);
        }
    }

    fn verify(&self, env: &Envelope) -> bool {
        match self.key_of(env) {
            Some(pk) => env.verify_with(&self.mont, pk),
            None => false,
        }
    }

    fn verify_batch(&self, envs: &[Envelope]) -> Vec<bool> {
        let mut ok = vec![false; envs.len()];
        // Envelopes lacking a signature or naming an unknown sender are
        // rejected outright; the rest enter the combined check.
        let msgs: Vec<Vec<u8>> = envs.iter().map(|e| e.signing_bytes()).collect();
        let mut items: Vec<(&PublicKey, &[u8], &Signature)> = Vec::with_capacity(envs.len());
        let mut idx: Vec<usize> = Vec::with_capacity(envs.len());
        for (i, env) in envs.iter().enumerate() {
            if let (Some(sig), Some(pk)) = (env.signature.as_ref(), self.key_of(env)) {
                items.push((pk, msgs[i].as_slice(), sig));
                idx.push(i);
            }
        }
        if batch_verify(&self.mont, &items) {
            for &i in &idx {
                ok[i] = true;
            }
        } else {
            // At least one forgery: fall back to per-envelope checks so
            // the bad envelope is attributed exactly — honest senders'
            // messages in the same batch must not be collateral.
            for (k, &i) in idx.iter().enumerate() {
                let (pk, msg, sig) = items[k];
                ok[i] = crate::crypto::verify(&self.mont, pk, msg, sig);
            }
        }
        ok
    }
}

/// The socket transport's session-MAC policy: the per-link stream MAC
/// (checked in the frame codec, before an envelope ever reaches the
/// mailbox) authenticates bulk traffic; adjudication-bound slots keep
/// real signatures. `verify` therefore demands a valid signature exactly
/// when [`requires_signature`] says the slot needs one, and trusts the
/// already-MAC-checked stream for the rest.
pub struct SessionAuth {
    inner: SchnorrAuth,
}

impl SessionAuth {
    pub fn new(mont: Mont, secret: Option<SecretKey>, public_keys: Vec<PublicKey>) -> SessionAuth {
        SessionAuth { inner: SchnorrAuth::new(mont, secret, public_keys) }
    }
}

impl MessageAuth for SessionAuth {
    fn seal(&self, env: &mut Envelope) {
        if requires_signature(env.slot) {
            self.inner.seal(env);
        }
    }

    fn verify(&self, env: &Envelope) -> bool {
        !requires_signature(env.slot) || self.inner.verify(env)
    }

    fn verify_batch(&self, envs: &[Envelope]) -> Vec<bool> {
        let mut ok = vec![true; envs.len()];
        let signed_idx: Vec<usize> = (0..envs.len())
            .filter(|&i| requires_signature(envs[i].slot))
            .collect();
        if signed_idx.is_empty() {
            return ok;
        }
        // Payloads are Arc-backed, so cloning the signed subset copies
        // pointers, not gradient buffers.
        let subset: Vec<Envelope> = signed_idx.iter().map(|&i| envs[i].clone()).collect();
        for (&i, verdict) in signed_idx.iter().zip(self.inner.verify_batch(&subset)) {
            ok[i] = verdict;
        }
        ok
    }
}

/// The `verify_signatures = false` benchmarking mode: no credentials,
/// everything accepted (by construction, not oversight — see the CLI's
/// `--no-sigs`).
pub struct NoAuth;

impl MessageAuth for NoAuth {
    fn seal(&self, _env: &mut Envelope) {}

    fn verify(&self, _env: &Envelope) -> bool {
        true
    }

    fn verify_batch(&self, envs: &[Envelope]) -> Vec<bool> {
        vec![true; envs.len()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::keygen;
    use crate::net::MsgClass;

    fn test_auth(n: usize) -> (Vec<SecretKey>, Vec<PublicKey>, Mont) {
        let mont = Mont::new();
        let secrets: Vec<SecretKey> = (0..n).map(|i| keygen(&mont, 4000 + i as u64)).collect();
        let publics = secrets.iter().map(|s| s.public).collect();
        (secrets, publics, mont)
    }

    fn envelope(from: usize, slot: u32, payload: Vec<u8>) -> Envelope {
        Envelope {
            from,
            step: 3,
            slot,
            class: MsgClass::Commitment,
            payload: payload.into(),
            broadcast: true,
            deliver_at: 0,
            signature: None,
        }
    }

    #[test]
    fn adjudication_slots_require_signatures() {
        for tag in [
            slots::GRAD_COMMIT,
            slots::AGG_COMMIT,
            slots::MPRNG_COMMIT,
            slots::MPRNG_REVEAL,
            slots::VERIFY_SCALARS,
            slots::CHECK_VOTE,
            slots::ACCUSE,
            slots::ELIMINATE,
            slots::VALIDATION_OK,
            slots::JOIN,
            slots::VERIFY_DONE,
            slots::LEAVE,
            slots::JOIN_REQUEST,
            slots::ROSTER_PROPOSE,
            slots::ROSTER_VOTE,
            slots::ROSTER_CERT,
        ] {
            assert!(requires_signature(slots::sub(tag, 7)), "tag {tag:#x}");
        }
        // The O(d) bulk payloads ride on the stream MAC alone.
        assert!(!requires_signature(slots::sub(slots::GRAD_PART, 7)));
        assert!(!requires_signature(slots::sub(slots::AGG_PART, 7)));
    }

    #[test]
    fn schnorr_auth_seals_and_batch_verifies() {
        let (secrets, publics, mont) = test_auth(4);
        let envs: Vec<Envelope> = (0..4)
            .map(|i| {
                let auth = SchnorrAuth::new(mont.clone(), Some(secrets[i].clone()), publics.clone());
                let mut env = envelope(i, slots::sub(slots::GRAD_COMMIT, i), vec![i as u8; 8]);
                auth.seal(&mut env);
                env
            })
            .collect();
        let verifier = SchnorrAuth::new(mont.clone(), None, publics.clone());
        assert!(envs.iter().all(|e| e.signature.is_some()));
        assert!(envs.iter().all(|e| verifier.verify(e)));
        assert_eq!(verifier.verify_batch(&envs), vec![true; 4]);
        assert_eq!(verifier.verify_batch(&[]), Vec::<bool>::new());
    }

    #[test]
    fn one_forgery_in_a_batch_is_attributed_to_the_right_envelope() {
        let (secrets, publics, mont) = test_auth(5);
        let mut envs: Vec<Envelope> = (0..5)
            .map(|i| {
                let auth = SchnorrAuth::new(mont.clone(), Some(secrets[i].clone()), publics.clone());
                let mut env = envelope(i, slots::sub(slots::ACCUSE, i), vec![7; 16]);
                auth.seal(&mut env);
                env
            })
            .collect();
        let verifier = SchnorrAuth::new(mont, None, publics);
        for bad in 0..envs.len() {
            // Tamper one envelope's payload after sealing: the combined
            // check fails, the fallback isolates exactly that index.
            let original = envs[bad].clone();
            envs[bad].payload = vec![0xEE; 16].into();
            let verdicts = verifier.verify_batch(&envs);
            for (i, &v) in verdicts.iter().enumerate() {
                assert_eq!(v, i != bad, "bad={bad} i={i}");
            }
            envs[bad] = original;
        }
        // An unsigned envelope is rejected without poisoning the batch.
        envs[2].signature = None;
        let verdicts = verifier.verify_batch(&envs);
        assert_eq!(verdicts, vec![true, true, false, true, true]);
    }

    #[test]
    fn session_auth_signs_only_adjudication_slots() {
        let (secrets, publics, mont) = test_auth(2);
        let auth = SessionAuth::new(mont.clone(), Some(secrets[0].clone()), publics.clone());
        let mut part = envelope(0, slots::sub(slots::GRAD_PART, 1), vec![1; 32]);
        auth.seal(&mut part);
        assert!(part.signature.is_none(), "bulk parts ride the stream MAC");
        let mut commit = envelope(0, slots::sub(slots::GRAD_COMMIT, 1), vec![2; 32]);
        auth.seal(&mut commit);
        assert!(commit.signature.is_some(), "commitments stay signed");

        let verifier = SessionAuth::new(mont, None, publics);
        assert!(verifier.verify(&part));
        assert!(verifier.verify(&commit));
        // An adjudication envelope stripped of its signature is rejected,
        // even though the (hypothetical) stream MAC admitted the frame.
        let mut stripped = commit.clone();
        stripped.signature = None;
        assert!(!verifier.verify(&stripped));
        assert_eq!(
            verifier.verify_batch(&[part, commit, stripped]),
            vec![true, true, false]
        );
    }

    #[test]
    fn noauth_accepts_everything() {
        let mut env = envelope(9, slots::sub(slots::GRAD_PART, 0), vec![1]);
        NoAuth.seal(&mut env);
        assert!(env.signature.is_none());
        assert!(NoAuth.verify(&env));
        assert_eq!(NoAuth.verify_batch(std::slice::from_ref(&env)), vec![true]);
    }
}
