//! Per-peer traffic accounting by message class.
//!
//! The paper's headline communication claim is that one BTARD step costs
//! each peer O(d + n²) bytes (vs O(d) for plain Butterfly All-Reduce and
//! O(n·d) for a robust parameter server). Accounting runs on two planes:
//!
//! - The **protocol plane** (`record_p2p` / `record_broadcast`) charges
//!   each *logical* send once, attributed to its message class. It is a
//!   pure function of the protocol transcript — identical across the
//!   in-process, simulated, and socket transports — which is what lets
//!   per-peer byte totals flow into the run's metrics digest.
//! - The **wire plane** (`record_wire` / `record_relay`) counts frames a
//!   transport *actually put on a wire*, including gossip relays of
//!   other peers' broadcasts. Only transports with a real wire record
//!   here; it is informational (benches, summaries), never digested.
//!
//! Earlier revisions charged broadcasts with a static `gossip_fanout`
//! multiplier on the protocol plane; now that the socket transport has a
//! real relay overlay, modelled costs live with the model and measured
//! costs with the wire.

use std::sync::Mutex;

/// Message classes (index into the per-peer counters).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MsgClass {
    /// Gradient partition payloads (the O(d) part).
    GradientPart = 0,
    /// Aggregated partition payloads (the other O(d) part).
    AggregatedPart = 1,
    /// Hash commitments (O(n) scalars broadcast → O(n²) per peer total).
    Commitment = 2,
    /// Inner products s_i^j and norms (O(n) scalars broadcast).
    Verification = 3,
    /// MPRNG commit/reveal messages.
    Mprng = 4,
    /// Accusations / eliminations / ban notices.
    Control = 5,
}

impl MsgClass {
    /// Inverse of `class as u8` (wire decoding); `None` for bytes that
    /// name no class — a malformed frame, rejected by the codec.
    pub fn from_u8(v: u8) -> Option<MsgClass> {
        Some(match v {
            0 => MsgClass::GradientPart,
            1 => MsgClass::AggregatedPart,
            2 => MsgClass::Commitment,
            3 => MsgClass::Verification,
            4 => MsgClass::Mprng,
            5 => MsgClass::Control,
            _ => return None,
        })
    }
}

pub const NUM_CLASSES: usize = 6;

pub const CLASS_NAMES: [&str; NUM_CLASSES] = [
    "gradient_part",
    "aggregated_part",
    "commitment",
    "verification",
    "mprng",
    "control",
];

#[derive(Clone, Debug, Default)]
pub struct PeerTraffic {
    /// Bytes sent, by class.
    pub bytes: [u64; NUM_CLASSES],
    /// Messages sent, by class.
    pub msgs: [u64; NUM_CLASSES],
}

/// Wire-plane counters for one peer: frames actually written to sockets.
#[derive(Clone, Debug, Default)]
pub struct PeerWire {
    /// Bytes written to sockets (own sends + relays).
    pub bytes: u64,
    /// Frames written to sockets (own sends + relays).
    pub msgs: u64,
    /// The subset of `bytes` spent relaying other peers' broadcasts.
    pub relay_bytes: u64,
    /// The subset of `msgs` spent relaying other peers' broadcasts.
    pub relay_msgs: u64,
}

/// Shared traffic accumulator for a simulated cluster.
#[derive(Debug)]
pub struct TrafficStats {
    peers: Mutex<Vec<PeerTraffic>>,
    wire: Mutex<Vec<PeerWire>>,
}

impl TrafficStats {
    pub fn new(n_peers: usize) -> TrafficStats {
        TrafficStats {
            peers: Mutex::new(vec![PeerTraffic::default(); n_peers]),
            wire: Mutex::new(vec![PeerWire::default(); n_peers]),
        }
    }

    /// Record a point-to-point send (protocol plane).
    pub fn record_p2p(&self, from: usize, class: MsgClass, bytes: usize) {
        let mut g = self.peers.lock().unwrap();
        let t = &mut g[from];
        t.bytes[class as usize] += bytes as u64;
        t.msgs[class as usize] += 1;
    }

    /// Record one logical broadcast (protocol plane): charged once,
    /// whatever fan-out the transport uses to disseminate it. Identical
    /// across transports by construction, so digests stay comparable.
    pub fn record_broadcast(&self, from: usize, class: MsgClass, bytes: usize) {
        let mut g = self.peers.lock().unwrap();
        let t = &mut g[from];
        t.bytes[class as usize] += bytes as u64;
        t.msgs[class as usize] += 1;
    }

    /// Record a frame actually written to a socket (wire plane).
    pub fn record_wire(&self, from: usize, bytes: usize) {
        let mut g = self.wire.lock().unwrap();
        let t = &mut g[from];
        t.bytes += bytes as u64;
        t.msgs += 1;
    }

    /// Record a relayed frame (wire plane): a broadcast originated by
    /// someone else, forwarded over this peer's overlay links.
    pub fn record_relay(&self, from: usize, bytes: usize) {
        let mut g = self.wire.lock().unwrap();
        let t = &mut g[from];
        t.bytes += bytes as u64;
        t.msgs += 1;
        t.relay_bytes += bytes as u64;
        t.relay_msgs += 1;
    }

    pub fn snapshot(&self) -> Vec<PeerTraffic> {
        self.peers.lock().unwrap().clone()
    }

    pub fn wire_snapshot(&self) -> Vec<PeerWire> {
        self.wire.lock().unwrap().clone()
    }

    /// Total protocol-plane bytes sent by a peer across all classes.
    pub fn total_bytes(&self, peer: usize) -> u64 {
        let g = self.peers.lock().unwrap();
        g[peer].bytes.iter().sum()
    }

    /// Total wire-plane bytes a peer wrote to sockets (0 on wireless
    /// transports).
    pub fn wire_bytes(&self, peer: usize) -> u64 {
        self.wire.lock().unwrap()[peer].bytes
    }

    /// Max over peers of total bytes (the per-peer cost the paper bounds).
    pub fn max_peer_bytes(&self) -> u64 {
        let g = self.peers.lock().unwrap();
        g.iter().map(|t| t.bytes.iter().sum::<u64>()).max().unwrap_or(0)
    }

    pub fn max_peer_wire_bytes(&self) -> u64 {
        let g = self.wire.lock().unwrap();
        g.iter().map(|t| t.bytes).max().unwrap_or(0)
    }

    pub fn reset(&self) {
        let mut g = self.peers.lock().unwrap();
        for t in g.iter_mut() {
            *t = PeerTraffic::default();
        }
        let mut w = self.wire.lock().unwrap();
        for t in w.iter_mut() {
            *t = PeerWire::default();
        }
    }

    /// Pretty summary table (used by the overhead bench).
    pub fn summary(&self) -> String {
        let g = self.peers.lock().unwrap();
        let mut out = String::new();
        let mut totals = [0u64; NUM_CLASSES];
        for t in g.iter() {
            for (i, b) in t.bytes.iter().enumerate() {
                totals[i] += b;
            }
        }
        let n = g.len().max(1) as u64;
        out.push_str("class                 total_bytes   avg_per_peer\n");
        for i in 0..NUM_CLASSES {
            out.push_str(&format!(
                "{:<20} {:>12} {:>14}\n",
                CLASS_NAMES[i],
                totals[i],
                totals[i] / n
            ));
        }
        drop(g);
        let w = self.wire.lock().unwrap();
        let (wb, rb): (u64, u64) = w.iter().fold((0, 0), |(b, r), t| (b + t.bytes, r + t.relay_bytes));
        if wb > 0 {
            out.push_str(&format!(
                "{:<20} {:>12} {:>14}\n",
                "wire (incl. relays)",
                wb,
                wb / n
            ));
            out.push_str(&format!("{:<20} {:>12} {:>14}\n", "  of which relays", rb, rb / n));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accounting() {
        let s = TrafficStats::new(2);
        s.record_p2p(0, MsgClass::GradientPart, 100);
        s.record_broadcast(0, MsgClass::Commitment, 32);
        s.record_p2p(1, MsgClass::AggregatedPart, 50);
        // A broadcast is one logical message on the protocol plane,
        // whatever the transport's fan-out.
        assert_eq!(s.total_bytes(0), 100 + 32);
        assert_eq!(s.total_bytes(1), 50);
        assert_eq!(s.max_peer_bytes(), 132);
        let snap = s.snapshot();
        assert_eq!(snap[0].msgs[MsgClass::Commitment as usize], 1);
        s.reset();
        assert_eq!(s.max_peer_bytes(), 0);
    }

    #[test]
    fn wire_plane_is_separate() {
        let s = TrafficStats::new(2);
        s.record_broadcast(0, MsgClass::Commitment, 32);
        // The transport wrote the frame to 3 overlay links...
        for _ in 0..3 {
            s.record_wire(0, 40);
        }
        // ...and peer 1 relayed it onward twice.
        s.record_relay(1, 40);
        s.record_relay(1, 40);
        assert_eq!(s.total_bytes(0), 32);
        assert_eq!(s.total_bytes(1), 0); // relays never hit the protocol plane
        assert_eq!(s.wire_bytes(0), 120);
        assert_eq!(s.wire_bytes(1), 80);
        assert_eq!(s.max_peer_wire_bytes(), 120);
        let w = s.wire_snapshot();
        assert_eq!(w[1].relay_msgs, 2);
        assert_eq!(w[0].relay_bytes, 0);
        s.reset();
        assert_eq!(s.max_peer_wire_bytes(), 0);
    }
}
