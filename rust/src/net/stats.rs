//! Per-peer traffic accounting by message class.
//!
//! The paper's headline communication claim is that one BTARD step costs
//! each peer O(d + n²) bytes (vs O(d) for plain Butterfly All-Reduce and
//! O(n·d) for a robust parameter server). These counters reproduce that
//! accounting: every send is attributed to its message class, and
//! broadcast messages are charged with the GossipSub relay factor D
//! (each peer relays a previously unseen message to D neighbours, so an
//! n-peer broadcast of b bytes costs O(n·b) total, O(b·D) per peer).

use std::sync::Mutex;

/// Message classes (index into the per-peer counters).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MsgClass {
    /// Gradient partition payloads (the O(d) part).
    GradientPart = 0,
    /// Aggregated partition payloads (the other O(d) part).
    AggregatedPart = 1,
    /// Hash commitments (O(n) scalars broadcast → O(n²) per peer total).
    Commitment = 2,
    /// Inner products s_i^j and norms (O(n) scalars broadcast).
    Verification = 3,
    /// MPRNG commit/reveal messages.
    Mprng = 4,
    /// Accusations / eliminations / ban notices.
    Control = 5,
}

impl MsgClass {
    /// Inverse of `class as u8` (wire decoding); `None` for bytes that
    /// name no class — a malformed frame, rejected by the codec.
    pub fn from_u8(v: u8) -> Option<MsgClass> {
        Some(match v {
            0 => MsgClass::GradientPart,
            1 => MsgClass::AggregatedPart,
            2 => MsgClass::Commitment,
            3 => MsgClass::Verification,
            4 => MsgClass::Mprng,
            5 => MsgClass::Control,
            _ => return None,
        })
    }
}

pub const NUM_CLASSES: usize = 6;

pub const CLASS_NAMES: [&str; NUM_CLASSES] = [
    "gradient_part",
    "aggregated_part",
    "commitment",
    "verification",
    "mprng",
    "control",
];

#[derive(Clone, Debug, Default)]
pub struct PeerTraffic {
    /// Bytes sent, by class.
    pub bytes: [u64; NUM_CLASSES],
    /// Messages sent, by class.
    pub msgs: [u64; NUM_CLASSES],
}

/// Shared traffic accumulator for a simulated cluster.
#[derive(Debug)]
pub struct TrafficStats {
    peers: Mutex<Vec<PeerTraffic>>,
    /// GossipSub fanout: relay cost multiplier applied to broadcasts.
    pub gossip_fanout: u64,
}

impl TrafficStats {
    pub fn new(n_peers: usize, gossip_fanout: u64) -> TrafficStats {
        TrafficStats {
            peers: Mutex::new(vec![PeerTraffic::default(); n_peers]),
            gossip_fanout,
        }
    }

    /// Record a point-to-point send.
    pub fn record_p2p(&self, from: usize, class: MsgClass, bytes: usize) {
        let mut g = self.peers.lock().unwrap();
        let t = &mut g[from];
        t.bytes[class as usize] += bytes as u64;
        t.msgs[class as usize] += 1;
    }

    /// Record a broadcast: the originator pays D relays' worth, modelling
    /// GossipSub's O(b·D) per-peer cost for an all-to-all broadcast.
    pub fn record_broadcast(&self, from: usize, class: MsgClass, bytes: usize) {
        let mut g = self.peers.lock().unwrap();
        let t = &mut g[from];
        t.bytes[class as usize] += bytes as u64 * self.gossip_fanout;
        t.msgs[class as usize] += self.gossip_fanout;
    }

    pub fn snapshot(&self) -> Vec<PeerTraffic> {
        self.peers.lock().unwrap().clone()
    }

    /// Total bytes sent by a peer across all classes.
    pub fn total_bytes(&self, peer: usize) -> u64 {
        let g = self.peers.lock().unwrap();
        g[peer].bytes.iter().sum()
    }

    /// Max over peers of total bytes (the per-peer cost the paper bounds).
    pub fn max_peer_bytes(&self) -> u64 {
        let g = self.peers.lock().unwrap();
        g.iter().map(|t| t.bytes.iter().sum::<u64>()).max().unwrap_or(0)
    }

    pub fn reset(&self) {
        let mut g = self.peers.lock().unwrap();
        for t in g.iter_mut() {
            *t = PeerTraffic::default();
        }
    }

    /// Pretty summary table (used by the overhead bench).
    pub fn summary(&self) -> String {
        let g = self.peers.lock().unwrap();
        let mut out = String::new();
        let mut totals = [0u64; NUM_CLASSES];
        for t in g.iter() {
            for (i, b) in t.bytes.iter().enumerate() {
                totals[i] += b;
            }
        }
        let n = g.len().max(1) as u64;
        out.push_str("class                 total_bytes   avg_per_peer\n");
        for i in 0..NUM_CLASSES {
            out.push_str(&format!(
                "{:<20} {:>12} {:>14}\n",
                CLASS_NAMES[i],
                totals[i],
                totals[i] / n
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accounting() {
        let s = TrafficStats::new(2, 8);
        s.record_p2p(0, MsgClass::GradientPart, 100);
        s.record_broadcast(0, MsgClass::Commitment, 32);
        s.record_p2p(1, MsgClass::AggregatedPart, 50);
        assert_eq!(s.total_bytes(0), 100 + 32 * 8);
        assert_eq!(s.total_bytes(1), 50);
        assert_eq!(s.max_peer_bytes(), 100 + 256);
        let snap = s.snapshot();
        assert_eq!(snap[0].msgs[MsgClass::Commitment as usize], 8);
        s.reset();
        assert_eq!(s.max_peer_bytes(), 0);
    }
}
