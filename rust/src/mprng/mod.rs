//! Multi-party random number generator (Appendix A.2).
//!
//! Generalized Blum (1983) coin tossing over the broadcast channel:
//! every peer commits `h_i = H(i ‖ x_i ‖ s_i)` to a random string `x_i`
//! with a large salt `s_i`, waits for all commitments, then reveals
//! `(x_i, s_i)`. The output is `x_1 ⊕ … ⊕ x_n`. Commit-before-reveal
//! means no peer can steer the result; peers whose reveal mismatches
//! their commitment (or who abort) are identified as offenders and
//! banned, which — per the paper — removes the residual abort-bias
//! (Cleve 1986) because the protocol restarts without them.
//!
//! This module is pure protocol logic (bytes in / bytes out); the
//! coordinator pumps the messages through the network layer, which keeps
//! it independently testable.

use crate::crypto::{commit, Digest, Opening};
use crate::net::PeerId;
use crate::util::rng::Rng;

pub const TAG: &[u8] = b"btard-mprng";
/// Output entropy per round (bytes of x_i).
pub const OUT_LEN: usize = 32;

/// One peer's view of an MPRNG round.
pub struct MprngRound {
    pub peer: PeerId,
    x: [u8; OUT_LEN],
    salt: [u8; 32],
}

impl MprngRound {
    /// Start a round: draw local randomness from `rng`.
    pub fn new(peer: PeerId, rng: &mut Rng) -> MprngRound {
        let mut x = [0u8; OUT_LEN];
        for b in x.iter_mut() {
            *b = rng.next_u32() as u8;
        }
        let mut salt = [0u8; 32];
        for b in salt.iter_mut() {
            *b = rng.next_u32() as u8;
        }
        MprngRound { peer, x, salt }
    }

    /// Commitment message payload (phase 1 broadcast).
    pub fn commitment(&self) -> Digest {
        commit(TAG, self.peer as u64, &self.x, &self.salt)
    }

    /// Reveal message payload (phase 2 broadcast): x_i ‖ s_i.
    pub fn reveal(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(OUT_LEN + 32);
        out.extend_from_slice(&self.x);
        out.extend_from_slice(&self.salt);
        out
    }
}

/// Parse a reveal payload.
pub fn parse_reveal(payload: &[u8]) -> Option<Opening> {
    if payload.len() != OUT_LEN + 32 {
        return None;
    }
    let mut salt = [0u8; 32];
    salt.copy_from_slice(&payload[OUT_LEN..]);
    Some(Opening { payload: payload[..OUT_LEN].to_vec(), salt })
}

/// Outcome of combining a round.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum MprngOutcome {
    /// Everyone opened correctly: the shared random output.
    Ok([u8; OUT_LEN]),
    /// These peers aborted or mismatched their commitment; they must be
    /// banned and the round restarted without them.
    Offenders(Vec<PeerId>),
}

/// Combine the collected commitments and reveals of the `live` peers.
///
/// `commitments[p]` / `reveals[p]` are `None` if peer p never sent that
/// phase (an abort). Offenders are: missing commitment, missing reveal,
/// malformed reveal, or reveal that does not match the commitment.
pub fn combine(
    live: &[PeerId],
    commitments: &[Option<Digest>],
    reveals: &[Option<Vec<u8>>],
) -> MprngOutcome {
    let mut offenders = Vec::new();
    let mut acc = [0u8; OUT_LEN];
    for &p in live {
        let c = match commitments.get(p).and_then(|c| *c) {
            Some(c) => c,
            None => {
                offenders.push(p);
                continue;
            }
        };
        let reveal = match reveals.get(p).and_then(|r| r.clone()) {
            Some(r) => r,
            None => {
                offenders.push(p);
                continue;
            }
        };
        let opening = match parse_reveal(&reveal) {
            Some(o) => o,
            None => {
                offenders.push(p);
                continue;
            }
        };
        if commit(TAG, p as u64, &opening.payload, &opening.salt) != c {
            offenders.push(p);
            continue;
        }
        for (a, b) in acc.iter_mut().zip(&opening.payload) {
            *a ^= b;
        }
    }
    if offenders.is_empty() {
        MprngOutcome::Ok(acc)
    } else {
        MprngOutcome::Offenders(offenders)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;

    fn run_round(n: usize, seed: u64) -> ([u8; OUT_LEN], Vec<MprngRound>) {
        let rounds: Vec<MprngRound> = (0..n)
            .map(|p| MprngRound::new(p, &mut Rng::new(seed + p as u64)))
            .collect();
        let live: Vec<PeerId> = (0..n).collect();
        let commitments: Vec<Option<Digest>> =
            rounds.iter().map(|r| Some(r.commitment())).collect();
        let reveals: Vec<Option<Vec<u8>>> = rounds.iter().map(|r| Some(r.reveal())).collect();
        match combine(&live, &commitments, &reveals) {
            MprngOutcome::Ok(out) => (out, rounds),
            MprngOutcome::Offenders(o) => panic!("unexpected offenders {o:?}"),
        }
    }

    #[test]
    fn honest_round_agrees() {
        let (out, _) = run_round(8, 42);
        let (out2, _) = run_round(8, 42);
        assert_eq!(out, out2); // deterministic given same local draws
        let (out3, _) = run_round(8, 43);
        assert_ne!(out, out3);
    }

    #[test]
    fn single_honest_peer_randomizes_output() {
        // Even if all other peers collude on fixed strings, one honest
        // peer's uniform x_i makes the XOR uniform: flipping the honest
        // draw changes the output.
        let n = 4;
        let live: Vec<PeerId> = (0..n).collect();
        let mk = |honest_seed: u64| {
            let rounds: Vec<MprngRound> = (0..n)
                .map(|p| {
                    let seed = if p == 0 { honest_seed } else { 7 }; // colluders reuse randomness
                    MprngRound::new(p, &mut Rng::new(seed))
                })
                .collect();
            let cs: Vec<_> = rounds.iter().map(|r| Some(r.commitment())).collect();
            let rs: Vec<_> = rounds.iter().map(|r| Some(r.reveal())).collect();
            match combine(&live, &cs, &rs) {
                MprngOutcome::Ok(o) => o,
                _ => panic!(),
            }
        };
        assert_ne!(mk(100), mk(101));
    }

    #[test]
    fn abort_detected() {
        let n = 3;
        let rounds: Vec<MprngRound> =
            (0..n).map(|p| MprngRound::new(p, &mut Rng::new(p as u64))).collect();
        let live: Vec<PeerId> = (0..n).collect();
        let cs: Vec<_> = rounds.iter().map(|r| Some(r.commitment())).collect();
        let mut rs: Vec<_> = rounds.iter().map(|r| Some(r.reveal())).collect();
        rs[1] = None; // peer 1 aborts after seeing others' reveals
        assert_eq!(combine(&live, &cs, &rs), MprngOutcome::Offenders(vec![1]));
    }

    #[test]
    fn mismatched_reveal_detected() {
        let n = 3;
        let rounds: Vec<MprngRound> =
            (0..n).map(|p| MprngRound::new(p, &mut Rng::new(10 + p as u64))).collect();
        let live: Vec<PeerId> = (0..n).collect();
        let cs: Vec<_> = rounds.iter().map(|r| Some(r.commitment())).collect();
        let mut rs: Vec<_> = rounds.iter().map(|r| Some(r.reveal())).collect();
        // Peer 2 tries to steer the output after seeing everyone else.
        let mut forged = rounds[2].reveal();
        forged[0] ^= 0xFF;
        rs[2] = Some(forged);
        assert_eq!(combine(&live, &cs, &rs), MprngOutcome::Offenders(vec![2]));
    }

    #[test]
    fn missing_commitment_detected() {
        let n = 2;
        let rounds: Vec<MprngRound> =
            (0..n).map(|p| MprngRound::new(p, &mut Rng::new(20 + p as u64))).collect();
        let live: Vec<PeerId> = (0..n).collect();
        let mut cs: Vec<_> = rounds.iter().map(|r| Some(r.commitment())).collect();
        cs[0] = None;
        let rs: Vec<_> = rounds.iter().map(|r| Some(r.reveal())).collect();
        assert_eq!(combine(&live, &cs, &rs), MprngOutcome::Offenders(vec![0]));
    }

    #[test]
    fn restart_without_offenders_succeeds() {
        let n = 4;
        let rounds: Vec<MprngRound> =
            (0..n).map(|p| MprngRound::new(p, &mut Rng::new(30 + p as u64))).collect();
        let cs: Vec<_> = rounds.iter().map(|r| Some(r.commitment())).collect();
        let mut rs: Vec<_> = rounds.iter().map(|r| Some(r.reveal())).collect();
        rs[3] = None;
        let live: Vec<PeerId> = (0..n).collect();
        let off = match combine(&live, &cs, &rs) {
            MprngOutcome::Offenders(o) => o,
            _ => panic!(),
        };
        let live2: Vec<PeerId> = live.into_iter().filter(|p| !off.contains(p)).collect();
        assert!(matches!(combine(&live2, &cs, &rs), MprngOutcome::Ok(_)));
    }

    #[test]
    fn output_is_xor_prop() {
        prop_check("xor structure", |rng, _| {
            let n = 2 + rng.below_usize(6);
            let rounds: Vec<MprngRound> =
                (0..n).map(|p| MprngRound::new(p, &mut Rng::new(rng.next_u64()))).collect();
            let live: Vec<PeerId> = (0..n).collect();
            let cs: Vec<_> = rounds.iter().map(|r| Some(r.commitment())).collect();
            let rs: Vec<_> = rounds.iter().map(|r| Some(r.reveal())).collect();
            let out = match combine(&live, &cs, &rs) {
                MprngOutcome::Ok(o) => o,
                _ => panic!(),
            };
            let mut expect = [0u8; OUT_LEN];
            for r in &rounds {
                for (a, b) in expect.iter_mut().zip(&r.x) {
                    *a ^= b;
                }
            }
            assert_eq!(out, expect);
        });
    }
}
