//! BTARD: Byzantine-Tolerant All-Reduce for secure distributed training.
//!
//! Reproduction of *Secure Distributed Training at Scale* (Gorbunov,
//! Borzunov, Diskin, Ryabinin — ICML 2022) as a three-layer
//! Rust + JAX + Pallas stack. See DESIGN.md for the system map.
//!
//! Layer 3 (this crate) owns the protocol: Butterfly All-Reduce with
//! CenteredClip aggregation, hash commitments, a commit-reveal multi-party
//! RNG, randomly drawn validators, ACCUSE/ELIMINATE ban protocols and the
//! training loops. Layers 1–2 (python/) are AOT-compiled to HLO text and
//! executed from `runtime` via PJRT; python never runs on the step path.

// Lint policy: CI runs `clippy -- -D warnings` as a blocking gate. These
// two style lints are allowed crate-wide because the protocol code hits
// them structurally (adjudication takes the full broadcast record as
// arguments; per-part state is nested row maps), not accidentally.
#![allow(clippy::too_many_arguments, clippy::type_complexity)]

pub mod coordinator;
pub mod crypto;
pub mod data;
pub mod harness;
pub mod model;
pub mod mprng;
pub mod net;
pub mod runtime;
pub mod util;
