//! BTARD: Byzantine-Tolerant All-Reduce for secure distributed training.
//!
//! Reproduction of *Secure Distributed Training at Scale* (Gorbunov,
//! Borzunov, Diskin, Ryabinin — ICML 2022) as a three-layer
//! Rust + JAX + Pallas stack. See DESIGN.md for the system map.
//!
//! Layer 3 (this crate) owns the protocol: Butterfly All-Reduce with
//! CenteredClip aggregation, hash commitments, a commit-reveal multi-party
//! RNG, randomly drawn validators, ACCUSE/ELIMINATE ban protocols and the
//! training loops. Layers 1–2 (python/) are AOT-compiled to HLO text and
//! executed from `runtime` via PJRT; python never runs on the step path.

pub mod coordinator;
pub mod crypto;
pub mod data;
pub mod harness;
pub mod model;
pub mod mprng;
pub mod net;
pub mod runtime;
pub mod util;
