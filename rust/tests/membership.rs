//! Dynamic-membership integration tests: the acceptance proof that
//! epoch-based churn (mid-run joins, graceful leaves, concurrent
//! attacks) is deterministic across every execution model, and that the
//! membership machinery never hurts honest peers.
//!
//! - A churn schedule (join mid-run + graceful leave) with a concurrent
//!   sign-flip attacker produces **identical metrics digests** across
//!   the threaded model, the pooled scheduler at several worker counts,
//!   and a loopback socket cluster (late links + epoch-stamped HELLOs).
//! - The attacker is banned while honest peers — including the joiner
//!   and the leaver — are never banned, and training converges.
//! - Owner/validator assignment invariants hold under arbitrary
//!   ban/join/leave sequences: every part and validator slot has exactly
//!   one live owner, and epoch-boundary assignment is a pure function of
//!   (epoch roster, seed).
//!
//! The *static*-roster guarantee (empty schedule ⇒ bit-identical to the
//! pre-membership code) is pinned by `rust/tests/golden_metrics.rs`.

use btard::coordinator::adversary::AdversarySpec;
use btard::coordinator::attacks::{AttackSchedule, CollusionBoard};
use btard::coordinator::centered_clip::TauPolicy;
use btard::coordinator::membership::MembershipSchedule;
use btard::coordinator::messages::BanReason;
use btard::coordinator::optimizer::LrSchedule;
use btard::coordinator::partition::OwnerMap;
use btard::coordinator::runconfig::WorkloadSpec;
use btard::coordinator::training::{
    peer_main, prepare_source, run_btard_pooled, run_btard_threaded, LifeSpan, OptSpec, RunConfig,
};
use btard::coordinator::ProtocolConfig;
use btard::crypto::Mont;
use btard::harness::{merge_reports, run_digest, PeerReport};
use btard::net::socket::SocketNet;
use btard::net::{
    bind_ephemeral, derive_keypair, NetworkProfile, Roster, RosterEntry, SocketConfig, Transport,
};
use btard::util::prop::prop_check;
use std::sync::Arc;
use std::time::Duration;

/// The cross-model churn scenario: a 6-id universe where peer 5 joins at
/// step 2 and peer 2 leaves gracefully at step 4, while peer 4 sign-flips
/// from step 3. Nesterov momentum is ON so the digest equality also
/// proves the JOIN snapshot's optimizer-state transfer is bit-exact (a
/// fresh momentum buffer on the joiner would diverge its params).
fn churn_cfg() -> RunConfig {
    RunConfig {
        n_peers: 6,
        byzantine: vec![4],
        attack: Some((
            AdversarySpec::parse("sign_flip:1000").unwrap(),
            AttackSchedule::from_step(3),
        )),
        steps: 6,
        protocol: ProtocolConfig {
            n0: 6,
            tau: TauPolicy::Fixed(1.0),
            m_validators: 2,
            delta_max: 4.0,
            ..ProtocolConfig::default()
        },
        opt: OptSpec::Sgd {
            schedule: LrSchedule::Constant(0.1),
            momentum: 0.9,
            nesterov: true,
        },
        clip_lambda: None,
        eval_every: 2,
        seed: 7,
        verify_signatures: false,
        gossip_fanout: 8,
        session_mac: false,
        network: NetworkProfile::perfect(),
        churn: MembershipSchedule::parse("join:5@2,leave:2@4").unwrap(),
        admission: Default::default(),
        segments: vec![],
        checkpoint: None,
    }
}

fn quad_workload() -> WorkloadSpec {
    WorkloadSpec::Quadratic { dim: 64, mu: 0.1, l: 2.0, sigma: 1.0, seed: 9 }
}

#[test]
fn churn_run_is_identical_across_exec_models_and_worker_counts() {
    let cfg = churn_cfg();
    let threaded = run_digest(&run_btard_threaded(&cfg, quad_workload().build()));
    let pooled2 = run_digest(&run_btard_pooled(&cfg, quad_workload().build(), 2));
    let pooled5 = run_digest(&run_btard_pooled(&cfg, quad_workload().build(), 5));
    assert_eq!(threaded, pooled2, "threaded vs pooled(2) under churn");
    assert_eq!(pooled2, pooled5, "pooled worker count must not matter under churn");
}

#[test]
fn churn_with_attacker_converges_and_honest_peers_are_unharmed() {
    // The acceptance scenario at full length: a joiner (9@2), a graceful
    // leaver (4@6), and a sign-flip attacker (8, from step 3) who is
    // caught by validator recomputation and banned; honest peers —
    // including the joiner and the leaver — are never banned, and the
    // quadratic converges.
    let mut cfg = RunConfig::quick(10, 24);
    cfg.byzantine = vec![8];
    cfg.attack = Some((
        AdversarySpec::parse("sign_flip:1000").unwrap(),
        AttackSchedule::from_step(3),
    ));
    cfg.churn = MembershipSchedule::parse("join:9@2,leave:4@6").unwrap();
    cfg.protocol.tau = TauPolicy::Fixed(2.0);
    cfg.protocol.m_validators = 4;
    cfg.protocol.delta_max = 10.0;
    cfg.opt = OptSpec::Sgd {
        schedule: LrSchedule::Constant(0.1),
        momentum: 0.0,
        nesterov: false,
    };
    cfg.eval_every = 4;
    cfg.verify_signatures = false;
    let src = Arc::new(btard::model::synthetic::Quadratic::new(64, 0.2, 4.0, 0.5, 7));
    let res = run_btard_pooled(&cfg, src, 4);
    assert_eq!(res.steps_done, 24, "churn must not end the run early");
    // The attacker is banned by gradient-recomputation evidence.
    let attacker_ban = res
        .ban_events
        .iter()
        .find(|b| b.target == 8)
        .unwrap_or_else(|| panic!("attacker 8 never banned: {:?}", res.ban_events));
    assert_eq!(attacker_ban.reason, BanReason::GradientMismatch, "{attacker_ban:?}");
    assert!(attacker_ban.step >= 3, "cannot be banned before attacking: {attacker_ban:?}");
    // No honest peer is ever banned — in particular neither the joiner
    // (9) nor the graceful leaver (4): leaving is not a ban.
    for b in &res.ban_events {
        assert_eq!(b.target, 8, "honest casualty: {b:?}");
    }
    assert!(
        res.final_metric < 1.0,
        "convergence under churn + attack, got {}",
        res.final_metric
    );
    // The joiner paid traffic only after its boundary; the leaver's row
    // is frozen at its departure. Both are real members of the digest.
    assert_eq!(res.peer_bytes.len(), 10);
    assert!(res.peer_bytes[9] > 0, "the joiner participated");
}

#[test]
fn joiner_momentum_state_is_load_bearing() {
    // Same scenario as the cross-model test but compared against a run
    // without churn: with Nesterov momentum on, the joiner's params only
    // stay consistent because the snapshot carries the optimizer state —
    // this test pins that the churn run actually *trains* (finite
    // metric, full length), i.e. the joiner never diverged and got
    // eliminated.
    let cfg = churn_cfg();
    let res = run_btard_pooled(&cfg, quad_workload().build(), 3);
    assert_eq!(res.steps_done, cfg.steps);
    assert!(res.final_metric.is_finite());
    // The joiner (5) must not appear in any ban event: a momentum
    // mismatch would desynchronize its params and surface as a
    // GradientMismatch / scalar ban against it.
    assert!(
        res.ban_events.iter().all(|b| b.target != 5),
        "joiner banned: {:?}",
        res.ban_events
    );
    // The graceful leaver (2) is likewise never a ban target.
    assert!(
        res.ban_events.iter().all(|b| b.target != 2),
        "leaver banned: {:?}",
        res.ban_events
    );
}

#[test]
fn owner_and_validator_assignment_invariants_under_arbitrary_churn() {
    // Satellite property: for any ban/join/leave sequence, every part
    // and validator slot has exactly one live owner, and epoch-boundary
    // assignment is a pure function of (epoch roster, step seed) —
    // independent of roster input order and of the path that produced
    // the roster.
    prop_check("membership owner invariants", |rng, _| {
        let n = 4 + rng.below_usize(20);
        let n_parts = n;
        let seed = rng.next_u64();
        let joiners: Vec<usize> = (1..n).filter(|_| rng.below(4) == 0).collect();
        let mut live: Vec<usize> = (0..n).filter(|p| !joiners.contains(p)).collect();
        if live.len() < 3 {
            return;
        }
        let mut pending = joiners;
        let mut epoch = 0u64;
        let mut owners = OwnerMap::derive(n_parts, &live, seed, epoch);
        let mut at_boundary = true;
        for _ in 0..12 {
            match rng.below(3) {
                0 => {
                    // Ban a random non-0 live peer (incremental path).
                    if live.len() > 2 {
                        let idx = 1 + rng.below_usize(live.len() - 1);
                        live.remove(idx);
                        owners.reassign_banned(&live);
                        at_boundary = false;
                    }
                }
                1 => {
                    // Epoch boundary: a join.
                    if let Some(j) = pending.pop() {
                        live.push(j);
                        live.sort_unstable();
                        epoch += 1;
                        owners = OwnerMap::derive(n_parts, &live, seed, epoch);
                        at_boundary = true;
                    }
                }
                _ => {
                    // Epoch boundary: a graceful leave.
                    if live.len() > 2 {
                        let idx = 1 + rng.below_usize(live.len() - 1);
                        live.remove(idx);
                        epoch += 1;
                        owners = OwnerMap::derive(n_parts, &live, seed, epoch);
                        at_boundary = true;
                    }
                }
            }
            // Every part has exactly one owner, and that owner is live.
            for j in 0..n_parts {
                assert!(live.contains(&owners.owner(j)), "part {j} owner not live");
            }
            // Epoch-boundary assignment is a pure function of the
            // (roster, seed, epoch) triple: recomputing from a shuffled
            // copy of the roster reproduces it exactly.
            if at_boundary {
                let mut shuffled = live.clone();
                rng.shuffle(&mut shuffled);
                let again = OwnerMap::derive(n_parts, &shuffled, seed, epoch);
                assert_eq!(owners.to_vec(), again.to_vec(), "derive must be pure");
            }
            // Validator slots: the REAL shared derivation (the one both
            // stage_finish and the membership boundary call) lands every
            // (validator, target) pair on live peers, and identical
            // inputs give identical draws.
            let r = btard::crypto::sha256_parts(&[b"prop-churn-r", &epoch.to_le_bytes()]);
            let validators = btard::coordinator::step::draw_validators(&live, &r, 2);
            for &(v, t) in &validators {
                assert!(live.contains(&v) && live.contains(&t));
            }
            assert_eq!(validators, btard::coordinator::step::draw_validators(&live, &r, 2));
        }
    });
}

#[test]
fn churn_composes_with_network_fault_simulation_deterministically() {
    // Churn over a lossy fabric: the joiner's ordinary traffic is
    // faulted normally from its boundary on (clock synchronized at
    // install), the JOIN snapshot rides the reliable control plane, and
    // the whole run stays a pure function of the seed — identical
    // digests at different worker counts.
    let mut cfg = churn_cfg();
    cfg.network = NetworkProfile::from_name("lossy:0.05").unwrap();
    let a = run_digest(&run_btard_pooled(&cfg, quad_workload().build(), 2));
    let b = run_digest(&run_btard_pooled(&cfg, quad_workload().build(), 5));
    assert_eq!(a, b, "lossy-fabric churn must be worker-count invariant");
    // The joiner is never orphaned by a faulted snapshot: it completes
    // the run as a live member (its traffic row is non-empty).
    let res = run_btard_pooled(&cfg, quad_workload().build(), 3);
    assert!(res.peer_bytes[5] > 0, "joiner must be admitted under faults: {res:?}");
}

/// Loopback socket cluster with a churn schedule: one endpoint per
/// thread, each with its own per-"process" state, sharing only the
/// roster — the in-test stand-in for true `btard peer` processes.
fn run_socket_churn_cluster(cfg: &RunConfig, workload: &WorkloadSpec) -> Vec<PeerReport> {
    let n = cfg.n_peers;
    let mont = Mont::new();
    let mut listeners = Vec::with_capacity(n);
    let mut entries = Vec::with_capacity(n);
    for k in 0..n {
        let (listener, addr) = bind_ephemeral().unwrap();
        entries.push(RosterEntry {
            id: k,
            addr,
            pubkey: derive_keypair(&mont, cfg.seed, k).public,
        });
        listeners.push(listener);
    }
    let roster = Roster { peers: entries };
    let mut handles = Vec::with_capacity(n);
    for (k, listener) in listeners.into_iter().enumerate() {
        let roster = roster.clone();
        let cfg = cfg.clone();
        let workload = workload.clone();
        handles.push(std::thread::spawn(move || {
            let mont = Mont::new();
            let secret = derive_keypair(&mont, cfg.seed, k);
            let scfg = SocketConfig {
                gossip_fanout: cfg.gossip_fanout,
                verify_signatures: cfg.verify_signatures,
                connect_timeout: Duration::from_secs(30),
                join_steps: cfg.churn.join_steps(cfg.n_peers),
                ..SocketConfig::default()
            };
            let net = SocketNet::connect(listener, &roster, k, secret, &scfg).unwrap();
            let info = net.info().clone();
            let source = prepare_source(&cfg, workload.build());
            let init_params = source.init_params(cfg.seed);
            let board = CollusionBoard::new();
            let out =
                peer_main(Box::new(net), cfg.clone(), source, init_params, board, LifeSpan::Whole);
            PeerReport::from_output(k, out, info.stats.total_bytes(k))
        }));
    }
    handles.into_iter().map(|h| h.join().expect("peer thread panicked")).collect()
}

#[test]
fn socket_churn_cluster_is_bit_identical_to_in_process_runs() {
    // 5-id universe over real loopback TCP, signatures ON: peer 4 joins
    // at step 2 (its links form lazily, via epoch-stamped HELLOs through
    // the background acceptors), peer 1 leaves gracefully at step 3, and
    // peer 3 sign-flips from step 2. The merged socket digest must equal
    // both in-process models' digests bit-for-bit.
    let cfg = RunConfig {
        n_peers: 5,
        byzantine: vec![3],
        attack: Some((
            AdversarySpec::parse("sign_flip:1000").unwrap(),
            AttackSchedule::from_step(2),
        )),
        steps: 4,
        protocol: ProtocolConfig {
            n0: 5,
            tau: TauPolicy::Fixed(1.0),
            m_validators: 1,
            delta_max: 4.0,
            ..ProtocolConfig::default()
        },
        opt: OptSpec::Sgd {
            schedule: LrSchedule::Constant(0.1),
            momentum: 0.0,
            nesterov: false,
        },
        clip_lambda: None,
        eval_every: 2,
        seed: 7,
        verify_signatures: true,
        gossip_fanout: 8,
        session_mac: false,
        network: NetworkProfile::perfect(),
        churn: MembershipSchedule::parse("join:4@2,leave:1@3").unwrap(),
        admission: Default::default(),
        segments: vec![],
        checkpoint: None,
    };
    let workload = quad_workload();

    let threaded = run_digest(&run_btard_threaded(&cfg, workload.build()));
    let pooled = run_digest(&run_btard_pooled(&cfg, workload.build(), 2));
    assert_eq!(threaded, pooled, "in-process execution models must agree first");

    let reports = run_socket_churn_cluster(&cfg, &workload);
    // The joiner paid traffic (it participated from step 2); the leaver
    // stopped at its boundary.
    assert!(reports[4].own_bytes > 0, "{reports:?}");
    assert_eq!(reports[1].steps_done, 3, "{reports:?}");
    let merged = merge_reports(cfg.n_peers, reports).unwrap();
    assert_eq!(
        run_digest(&merged),
        threaded,
        "a perfect-link socket cluster with churn must reproduce the in-process digest"
    );
}
