//! Bit-identity gate for the runtime-dispatched vector kernels.
//!
//! Every SIMD level this machine supports must produce *byte-identical*
//! results to the portable scalar reference — not "close", identical:
//! CenteredClip norms and deltas feed commit hashes, and the golden
//! 64-peer digest pins the whole pipeline. The sweeps deliberately hit
//! non-multiple-of-lane-width shapes (tails), unaligned starting
//! offsets (subslicing defeats any accidental alignment assumption),
//! and a τ range spanning no-clip and everything-clipped.
//!
//! The final test re-runs the golden 64-peer scenario with the kernels
//! forced to each available level and asserts the run digest never
//! moves — kernel selection is compute state, not protocol state.

use btard::coordinator::adversary::AdversarySpec;
use btard::coordinator::attacks::AttackSchedule;
use btard::coordinator::centered_clip::TauPolicy;
use btard::coordinator::membership::MembershipSchedule;
use btard::coordinator::optimizer::LrSchedule;
use btard::coordinator::training::{run_btard_pooled, OptSpec, RunConfig};
use btard::coordinator::ProtocolConfig;
use btard::crypto::{
    hmac_sha256, hmac_sha256_batch, sha256, sha256_batch, sha256_batch_f32, sha256_batch_parts,
    sha256_f32, sha256_parts,
};
use btard::harness::run_digest;
use btard::model::synthetic::Quadratic;
use btard::model::GradientSource;
use btard::net::NetworkProfile;
use btard::util::kernels::{self, apply, clip, Level};
use btard::util::rng::Rng;
use std::sync::Arc;

/// Dimension sweep: below one vector, exactly one vector, straddling
/// the 4/8-lane widths and the pass-A 4-row block, plus larger shapes
/// that exercise several full vectors *and* a tail.
const DIMS: &[usize] = &[0, 1, 3, 4, 5, 7, 8, 9, 15, 16, 17, 31, 33, 64, 100, 257, 1024, 1031];
const ROWS: &[usize] = &[1, 2, 3, 4, 5, 7, 8, 9, 16, 63];
const TAUS: &[f32] = &[0.0, 0.5, 1.0, 2.0, 1e6, f32::INFINITY];

fn gaussian_vec(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    let mut v = vec![0.0f32; n];
    rng.fill_gaussian(&mut v, scale);
    v
}

/// The levels to test against scalar on this machine (may be just
/// [Scalar] on a non-x86_64 or pre-SSE2-detected host — then the tests
/// degenerate to scalar==scalar, which is fine: CI has AVX2).
fn simd_levels() -> Vec<Level> {
    Level::available().into_iter().filter(|&l| l != Level::Scalar).collect()
}

#[test]
fn clip_row_norms_bit_identical_across_levels() {
    let mut rng = Rng::new(0xA11CE);
    for &level in &simd_levels() {
        for &rows_n in ROWS {
            for &dim in DIMS {
                // +3 then subslice: the kernel sees an unaligned window.
                let storage: Vec<Vec<f32>> =
                    (0..rows_n).map(|_| gaussian_vec(&mut rng, dim + 3, 1.0)).collect();
                let rows: Vec<&[f32]> = storage.iter().map(|r| &r[3..]).collect();
                let v_store = gaussian_vec(&mut rng, dim + 3, 0.5);
                let v = &v_store[3..];

                let mut want = vec![0.0f64; rows_n];
                clip::row_norms_sq(Level::Scalar, &rows, v, &mut want);
                let mut got = vec![0.0f64; rows_n];
                clip::row_norms_sq(level, &rows, v, &mut got);
                for (i, (w, g)) in want.iter().zip(&got).enumerate() {
                    assert_eq!(
                        w.to_bits(),
                        g.to_bits(),
                        "row_norms_sq {} row {i} (rows={rows_n} dim={dim}): {w:e} vs {g:e}",
                        level.name()
                    );
                }
            }
        }
    }
}

#[test]
fn clip_delta_bit_identical_across_levels_and_taus() {
    let mut rng = Rng::new(0xBEEF);
    for &level in &simd_levels() {
        for &rows_n in &[1usize, 3, 4, 7, 16] {
            for &dim in DIMS {
                let storage: Vec<Vec<f32>> =
                    (0..rows_n).map(|_| gaussian_vec(&mut rng, dim + 1, 1.0)).collect();
                let rows: Vec<&[f32]> = storage.iter().map(|r| &r[1..]).collect();
                let v_store = gaussian_vec(&mut rng, dim + 1, 0.5);
                let v = &v_store[1..];
                for &tau in TAUS {
                    let mut norms = vec![0.0f64; rows_n];
                    clip::row_norms_sq(Level::Scalar, &rows, v, &mut norms);
                    let weights: Vec<f32> = norms
                        .iter()
                        .map(|&nsq| {
                            btard::coordinator::centered_clip::clip_weight(nsq.sqrt() as f32, tau)
                        })
                        .collect();
                    // Chunked at a non-lane-multiple width so chunk
                    // boundaries land mid-vector.
                    let chunk = 13;
                    let mut want = vec![0.0f32; dim];
                    for (c, d) in want.chunks_mut(chunk).enumerate() {
                        clip::delta_chunk(Level::Scalar, &rows, v, &weights, d, c * chunk);
                    }
                    let mut got = vec![0.0f32; dim];
                    for (c, d) in got.chunks_mut(chunk).enumerate() {
                        clip::delta_chunk(level, &rows, v, &weights, d, c * chunk);
                    }
                    for (k, (w, g)) in want.iter().zip(&got).enumerate() {
                        assert_eq!(
                            w.to_bits(),
                            g.to_bits(),
                            "delta {} k={k} (rows={rows_n} dim={dim} tau={tau}): {w:e} vs {g:e}",
                            level.name()
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn optimizer_applies_bit_identical_across_levels() {
    let mut rng = Rng::new(0x0917);
    for &level in &simd_levels() {
        for &dim in DIMS {
            let grad = gaussian_vec(&mut rng, dim, 1.0);
            let p0 = gaussian_vec(&mut rng, dim, 0.3);
            let v0 = gaussian_vec(&mut rng, dim, 0.1);

            for &(momentum, wd, nesterov) in
                &[(0.0f32, 0.0f32, false), (0.9, 1e-4, false), (0.9, 1e-4, true)]
            {
                let (mut ps, mut vs) = (p0.clone(), v0.clone());
                apply::sgd_apply(Level::Scalar, &mut ps, &mut vs, &grad, 0.05, momentum, wd, nesterov);
                let (mut pl, mut vl) = (p0.clone(), v0.clone());
                apply::sgd_apply(level, &mut pl, &mut vl, &grad, 0.05, momentum, wd, nesterov);
                assert!(
                    ps.iter().zip(&pl).all(|(a, b)| a.to_bits() == b.to_bits())
                        && vs.iter().zip(&vl).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "sgd_apply {} diverged (dim={dim} momentum={momentum} nesterov={nesterov})",
                    level.name()
                );
            }

            let m0 = gaussian_vec(&mut rng, dim, 0.01);
            let w0 = gaussian_vec(&mut rng, dim, 0.01).iter().map(|x| x * x).collect::<Vec<_>>();
            let (mut ms, mut qs, mut us) = (m0.clone(), w0.clone(), vec![0.0f32; dim]);
            apply::lamb_moments(
                Level::Scalar, &mut ms, &mut qs, &grad, &p0, &mut us, 0.9, 0.999, 0.1, 0.001,
                1e-6, 0.01,
            );
            let (mut ml, mut ql, mut ul) = (m0.clone(), w0.clone(), vec![0.0f32; dim]);
            apply::lamb_moments(
                level, &mut ml, &mut ql, &grad, &p0, &mut ul, 0.9, 0.999, 0.1, 0.001, 1e-6, 0.01,
            );
            assert!(
                ms.iter().zip(&ml).all(|(a, b)| a.to_bits() == b.to_bits())
                    && qs.iter().zip(&ql).all(|(a, b)| a.to_bits() == b.to_bits())
                    && us.iter().zip(&ul).all(|(a, b)| a.to_bits() == b.to_bits()),
                "lamb_moments {} diverged (dim={dim})",
                level.name()
            );

            let mut pss = p0.clone();
            apply::scaled_sub(Level::Scalar, &mut pss, &us, 0.0123);
            let mut pls = p0.clone();
            apply::scaled_sub(level, &mut pls, &ul, 0.0123);
            assert!(
                pss.iter().zip(&pls).all(|(a, b)| a.to_bits() == b.to_bits()),
                "scaled_sub {} diverged (dim={dim})",
                level.name()
            );
        }
    }
}

#[test]
fn sha256_batches_bit_identical_across_levels() {
    // Mixed lengths spanning padding block-count buckets (0..=3 blocks),
    // with enough messages to fill 8-lane groups plus a ragged tail.
    let msgs: Vec<Vec<u8>> = (0..23u8)
        .map(|i| {
            let len = [0usize, 1, 54, 55, 56, 63, 64, 65, 119, 120, 128, 200][i as usize % 12]
                + (i as usize % 3);
            (0..len).map(|j| i.wrapping_mul(37).wrapping_add(j as u8)).collect()
        })
        .collect();
    let refs: Vec<&[u8]> = msgs.iter().map(|m| m.as_slice()).collect();
    let want: Vec<[u8; 32]> = refs.iter().map(|m| sha256(m)).collect();

    let parts_items: Vec<[&[u8]; 3]> =
        refs.iter().map(|m| [b"prefix".as_slice(), m, b"suffix".as_slice()]).collect();
    let parts_refs: Vec<&[&[u8]]> = parts_items.iter().map(|p| p.as_slice()).collect();
    let want_parts: Vec<[u8; 32]> = parts_refs.iter().map(|p| sha256_parts(p)).collect();

    let floats: Vec<Vec<f32>> = (0..9).map(|i| vec![1.5f32 + i as f32; 5 + i * 7]).collect();
    let float_refs: Vec<&[f32]> = floats.iter().map(|f| f.as_slice()).collect();
    let want_f32: Vec<[u8; 32]> = float_refs.iter().map(|f| sha256_f32(f)).collect();

    let keys: Vec<Vec<u8>> = (0..13).map(|i| vec![i as u8; [16, 32, 64, 65, 200][i % 5]]).collect();
    let hmac_parts: Vec<[&[u8]; 2]> =
        keys.iter().zip(&refs).map(|(_, m)| [b"frame".as_slice(), *m]).collect();
    let hmac_items: Vec<(&[u8], &[&[u8]])> = keys
        .iter()
        .zip(&hmac_parts)
        .map(|(k, p)| (k.as_slice(), p.as_slice()))
        .collect();
    let want_hmac: Vec<[u8; 32]> =
        hmac_items.iter().map(|(k, p)| hmac_sha256(k, p)).collect();

    for level in Level::available() {
        kernels::with_forced_level(level, || {
            assert_eq!(sha256_batch(&refs), want, "sha256_batch at {}", level.name());
            assert_eq!(
                sha256_batch_parts(&parts_refs),
                want_parts,
                "sha256_batch_parts at {}",
                level.name()
            );
            assert_eq!(
                sha256_batch_f32(&float_refs),
                want_f32,
                "sha256_batch_f32 at {}",
                level.name()
            );
            assert_eq!(
                hmac_sha256_batch(&hmac_items),
                want_hmac,
                "hmac_sha256_batch at {}",
                level.name()
            );
        });
    }
}

/// The golden 64-peer scenario (same shape golden_metrics.rs pins): the
/// run digest must be identical with the kernels forced to every level
/// this machine supports. This is the end-to-end closure of the
/// bit-exactness contract — norms, deltas, optimizer steps and every
/// commit/accusation hash flow through the kernels.
#[test]
fn golden_64_peer_digest_invariant_across_kernel_levels() {
    let cfg = RunConfig {
        n_peers: 64,
        byzantine: (56..64).collect(),
        attack: Some((
            AdversarySpec::parse("sign_flip:1000").unwrap(),
            AttackSchedule::from_step(2),
        )),
        steps: 4,
        protocol: ProtocolConfig {
            n0: 64,
            tau: TauPolicy::Fixed(1.0),
            m_validators: 8,
            delta_max: 4.0,
            ..ProtocolConfig::default()
        },
        opt: OptSpec::Sgd {
            schedule: LrSchedule::Constant(0.1),
            momentum: 0.0,
            nesterov: false,
        },
        clip_lambda: None,
        eval_every: 2,
        seed: 7,
        verify_signatures: false,
        gossip_fanout: 8,
        session_mac: false,
        network: NetworkProfile::perfect(),
        churn: MembershipSchedule::empty(),
        admission: Default::default(),
        segments: vec![],
        checkpoint: None,
    };
    let run_at = |level: Level| {
        kernels::with_forced_level(level, || {
            let src: Arc<dyn GradientSource> = Arc::new(Quadratic::new(1024, 0.1, 2.0, 1.0, 9));
            run_digest(&run_btard_pooled(&cfg, src, 4))
        })
    };
    let scalar = run_at(Level::Scalar);
    for level in Level::available() {
        let digest = run_at(level);
        assert_eq!(
            digest,
            scalar,
            "64-peer run digest moved between scalar and {} kernels",
            level.name()
        );
    }
}
