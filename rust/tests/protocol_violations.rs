//! Attack-taxonomy integration tests (Appendix C): every class of
//! Byzantine violation must end with the offender banned, and honest
//! peers must never be banned except through the mutual-elimination
//! trade (at most one honest per Byzantine).
//!
//! The `run_btard` tests use the default execution model (the pooled
//! scheduler, unless BTARD_EXEC overrides it); the `direct` module
//! drives `btard_step` on real per-peer threads with blocking receives.
//! All runs use real signatures, commitments and MPRNG — these are
//! full-protocol tests, just on small synthetic objectives so they stay
//! fast on the 1-core testbed.

use btard::coordinator::attacks::{AttackKind, AttackSchedule, AttackState, CollusionBoard};
use btard::coordinator::centered_clip::TauPolicy;
use btard::coordinator::messages::BanReason;
use btard::coordinator::optimizer::LrSchedule;
use btard::coordinator::step::{Behavior, ByzantineConfig};
use btard::coordinator::training::{run_btard, OptSpec, RunConfig};
use btard::model::synthetic::Quadratic;
use btard::model::GradientSource;
use std::sync::Arc;

fn quad() -> Arc<dyn GradientSource> {
    Arc::new(Quadratic::new(64, 0.2, 4.0, 0.5, 7))
}

fn base_cfg(n: usize, byz: Vec<usize>, steps: u64) -> RunConfig {
    let mut cfg = RunConfig::quick(n, steps);
    cfg.byzantine = byz;
    cfg.protocol.tau = TauPolicy::Fixed(2.0);
    cfg.protocol.delta_max = 5.0;
    cfg.opt = OptSpec::Sgd {
        schedule: LrSchedule::Constant(0.3),
        momentum: 0.0,
        nesterov: false,
    };
    cfg
}

#[test]
fn honest_run_never_bans() {
    let cfg = base_cfg(4, vec![], 30);
    let res = run_btard(&cfg, quad());
    assert!(res.ban_events.is_empty(), "bans in honest run: {:?}", res.ban_events);
    assert_eq!(res.steps_done, 30);
    // 30 steps is not enough to converge; just check improvement.
    let first = res.metrics.iter().find(|m| !m.metric.is_nan()).unwrap().metric;
    assert!(res.final_metric < first, "{first} -> {}", res.final_metric);
}

#[test]
fn gradient_attacker_is_banned_and_training_recovers() {
    let mut cfg = base_cfg(4, vec![3], 120);
    cfg.attack = Some((
        AttackKind::SignFlip { lambda: 1000.0 },
        AttackSchedule::from_step(10),
    ));
    let res = run_btard(&cfg, quad());
    let ban = res
        .ban_events
        .iter()
        .find(|b| b.target == 3)
        .expect("attacker must be banned");
    assert_eq!(ban.reason, BanReason::GradientMismatch);
    assert!(ban.step >= 10, "banned before attacking?");
    // No honest peer banned.
    assert!(res.ban_events.iter().all(|b| b.target == 3));
    assert!(res.final_metric < 1.0, "no recovery: {}", res.final_metric);
}

#[test]
fn random_direction_attacker_is_banned() {
    let mut cfg = base_cfg(4, vec![2], 100);
    cfg.attack = Some((
        AttackKind::RandomDirection { lambda: 1000.0 },
        AttackSchedule::from_step(8),
    ));
    let res = run_btard(&cfg, quad());
    assert!(res.ban_events.iter().any(|b| b.target == 2), "{:?}", res.ban_events);
    assert!(res.ban_events.iter().all(|b| b.target == 2));
}

#[test]
fn two_colluding_attackers_both_banned() {
    let mut cfg = base_cfg(6, vec![4, 5], 150);
    cfg.attack = Some((
        AttackKind::SignFlip { lambda: 500.0 },
        AttackSchedule::from_step(10),
    ));
    let res = run_btard(&cfg, quad());
    for byz in [4usize, 5] {
        assert!(
            res.ban_events.iter().any(|b| b.target == byz),
            "peer {byz} not banned: {:?}",
            res.ban_events
        );
    }
    assert!(res.ban_events.iter().all(|b| b.target == 4 || b.target == 5));
}

#[test]
fn ipm_attacker_is_banned() {
    // IPM sends −ε·mean(honest) — a *plausible-looking* gradient, but it
    // does not match any hash-committed honest computation, so a
    // validator recomputing from the public seed catches it.
    let mut cfg = base_cfg(4, vec![3], 120);
    cfg.attack = Some((AttackKind::Ipm { eps: 0.6 }, AttackSchedule::from_step(5)));
    let res = run_btard(&cfg, quad());
    assert!(res.ban_events.iter().any(|b| b.target == 3), "{:?}", res.ban_events);
}

// --- direct protocol-violation behaviours (test hooks) ----------------------

mod direct {
    use super::*;
    use btard::coordinator::partition::{OwnerMap, PartitionSpec};
    use btard::coordinator::step::{btard_step, PeerCtx, ProtocolConfig};
    use btard::net::local::build_cluster;
    use btard::util::rng::Rng;

    /// Drive a 4-peer cluster manually with one misbehaving peer built
    /// from `mk_behavior`, for `steps` steps; returns peer 0's ledger.
    fn run_manual(
        mk_behavior: impl Fn(usize) -> Behavior + Send + Sync,
        steps: u64,
    ) -> btard::coordinator::BanLedger {
        let n = 4;
        let source = quad();
        let params0 = source.init_params(0);
        let cluster = build_cluster(n, 900, 8, true);
        let mut handles = Vec::new();
        for net in cluster {
            let peer = net.id;
            let source = source.clone();
            let params0 = params0.clone();
            let behavior = mk_behavior(peer);
            let h = std::thread::spawn(move || {
                let cfgp = ProtocolConfig {
                    n0: n,
                    tau: TauPolicy::Fixed(2.0),
                    delta_max: 5.0,
                    ..ProtocolConfig::default()
                };
                let r0 = btard::crypto::sha256_parts(&[b"manual", &1u64.to_le_bytes()]);
                let mut ctx = PeerCtx {
                    net: Box::new(net),
                    cfg: cfgp,
                    source,
                    spec: PartitionSpec::new(params0.len(), n),
                    owners: OwnerMap::initial(n),
                    live: (0..n).collect(),
                    ledger: btard::coordinator::BanLedger::new(),
                    equiv: btard::net::gossip::EquivocationTracker::new(),
                    behavior,
                    local_rng: Rng::new(1000 + peer as u64),
                    r_prev: r0,
                    validators: vec![],
                    archive: None,
                    recompute_count: 0,
                };
                let mut params = params0;
                for step in 0..steps {
                    match btard_step(&mut ctx, step, &params) {
                        Ok(out) => {
                            for (p, g) in params.iter_mut().zip(&out.aggregated) {
                                *p -= 0.05 * g;
                            }
                        }
                        Err(_) => break,
                    }
                    if ctx.ledger.is_banned(peer) {
                        break;
                    }
                }
                (peer, ctx.ledger)
            });
            handles.push(h);
        }
        let mut ledger0 = None;
        for h in handles {
            let (peer, ledger) = h.join().expect("peer thread");
            if peer == 0 {
                ledger0 = Some(ledger);
            }
        }
        ledger0.unwrap()
    }

    fn byz(cfg_fn: impl Fn(&mut ByzantineConfig)) -> Behavior {
        let mut b = ByzantineConfig {
            attack: AttackState::new(
                AttackKind::SignFlip { lambda: 1.0 },
                AttackSchedule::from_step(u64::MAX), // gradient attack off
                CollusionBoard::new(),
            ),
            aggregation_attack: false,
            aggregation_shift: 2.0,
            lazy_validator: true,
            equivocate: false,
            withhold_part_from: None,
            wrong_scalars: false,
        };
        cfg_fn(&mut b);
        Behavior::Byzantine(Box::new(b))
    }

    #[test]
    fn equivocation_is_banned_first_step() {
        let ledger = run_manual(
            |p| {
                if p == 2 {
                    byz(|b| b.equivocate = true)
                } else {
                    Behavior::Honest
                }
            },
            3,
        );
        let ev = ledger.events.iter().find(|e| e.target == 2).expect("equivocator banned");
        assert_eq!(ev.reason, BanReason::Equivocation);
        assert_eq!(ev.step, 0);
        assert!(ledger.events.iter().all(|e| e.target == 2));
    }

    #[test]
    fn withholding_triggers_mutual_elimination() {
        let ledger = run_manual(
            |p| {
                if p == 3 {
                    byz(|b| b.withhold_part_from = Some(1))
                } else {
                    Behavior::Honest
                }
            },
            3,
        );
        // Peer 1 never gets its part from 3 → ELIMINATE(1,3): both out.
        assert!(ledger.is_banned(3), "{:?}", ledger.events);
        assert!(ledger.is_banned(1), "{:?}", ledger.events);
        assert_eq!(ledger.banned_set().len(), 2);
    }

    #[test]
    fn aggregation_attack_is_banned() {
        let ledger = run_manual(
            |p| {
                if p == 1 {
                    byz(|b| {
                        b.aggregation_attack = true;
                        b.attack.schedule = AttackSchedule::from_step(1);
                    })
                } else {
                    Behavior::Honest
                }
            },
            40,
        );
        assert!(ledger.is_banned(1), "aggregation attacker not banned: {:?}", ledger.events);
        // Only the attacker is removed.
        assert_eq!(ledger.banned_set().len(), 1);
    }

    #[test]
    fn wrong_scalars_banned_via_owner_check() {
        let ledger = run_manual(
            |p| {
                if p == 2 {
                    byz(|b| {
                        b.wrong_scalars = true;
                        b.attack.schedule = AttackSchedule::from_step(0);
                    })
                } else {
                    Behavior::Honest
                }
            },
            10,
        );
        let ev = ledger.events.iter().find(|e| e.target == 2).expect("liar banned");
        assert!(
            matches!(
                ev.reason,
                BanReason::InnerProductMismatch
                    | BanReason::AggregationMismatch
                    | BanReason::GradientMismatch
            ),
            "{:?}",
            ev
        );
        assert!(ledger.events.iter().all(|e| e.target == 2));
    }
}
