//! Attack-taxonomy integration tests (Appendix C): every class of
//! Byzantine violation must end with the offender banned, and honest
//! peers must never be banned except through the mutual-elimination
//! trade (at most one honest per Byzantine).
//!
//! Every violation is driven through the pluggable `Adversary` API: the
//! gradient zoo and the protocol-surface adversaries (equivocation,
//! scalar lies, aggregation corruption, withholding, false accusations,
//! MPRNG abuse) all run end-to-end via `RunConfig.attack` specs — the
//! same path the CLI's `--attack` and the scenario matrix use. The
//! `custom` module additionally proves the trait is open: a bespoke
//! adversary defined *here*, outside the registry, plugs into the same
//! protocol loop. All runs use real signatures, commitments and MPRNG —
//! these are full-protocol tests, just on small synthetic objectives so
//! they stay fast on the 1-core testbed.

use btard::coordinator::adversary::AdversarySpec;
use btard::coordinator::attacks::AttackSchedule;
use btard::coordinator::centered_clip::TauPolicy;
use btard::coordinator::messages::BanReason;
use btard::coordinator::optimizer::LrSchedule;
use btard::coordinator::training::{run_btard, OptSpec, RunConfig};
use btard::model::synthetic::Quadratic;
use btard::model::GradientSource;
use std::sync::Arc;

fn quad() -> Arc<dyn GradientSource> {
    Arc::new(Quadratic::new(64, 0.2, 4.0, 0.5, 7))
}

fn base_cfg(n: usize, byz: Vec<usize>, steps: u64) -> RunConfig {
    let mut cfg = RunConfig::quick(n, steps);
    cfg.byzantine = byz;
    cfg.protocol.tau = TauPolicy::Fixed(2.0);
    cfg.protocol.delta_max = 5.0;
    cfg.opt = OptSpec::Sgd {
        schedule: LrSchedule::Constant(0.3),
        momentum: 0.0,
        nesterov: false,
    };
    cfg
}

fn attack(cfg: &mut RunConfig, spec: &str, start: u64) {
    cfg.attack = Some((
        AdversarySpec::parse(spec).expect("test attack spec"),
        AttackSchedule::from_step(start),
    ));
}

#[test]
fn honest_run_never_bans() {
    let cfg = base_cfg(4, vec![], 30);
    let res = run_btard(&cfg, quad());
    assert!(res.ban_events.is_empty(), "bans in honest run: {:?}", res.ban_events);
    assert_eq!(res.steps_done, 30);
    // 30 steps is not enough to converge; just check improvement.
    let first = res.metrics.iter().find(|m| !m.metric.is_nan()).unwrap().metric;
    assert!(res.final_metric < first, "{first} -> {}", res.final_metric);
}

#[test]
fn gradient_attacker_is_banned_and_training_recovers() {
    let mut cfg = base_cfg(4, vec![3], 120);
    attack(&mut cfg, "sign_flip:1000", 10);
    let res = run_btard(&cfg, quad());
    let ban = res
        .ban_events
        .iter()
        .find(|b| b.target == 3)
        .expect("attacker must be banned");
    assert_eq!(ban.reason, BanReason::GradientMismatch);
    assert!(ban.step >= 10, "banned before attacking?");
    // No honest peer banned.
    assert!(res.ban_events.iter().all(|b| b.target == 3));
    assert!(res.final_metric < 1.0, "no recovery: {}", res.final_metric);
}

#[test]
fn random_direction_attacker_is_banned() {
    let mut cfg = base_cfg(4, vec![2], 100);
    attack(&mut cfg, "random_direction:1000", 8);
    let res = run_btard(&cfg, quad());
    assert!(res.ban_events.iter().any(|b| b.target == 2), "{:?}", res.ban_events);
    assert!(res.ban_events.iter().all(|b| b.target == 2));
}

#[test]
fn two_colluding_attackers_both_banned() {
    let mut cfg = base_cfg(6, vec![4, 5], 150);
    attack(&mut cfg, "sign_flip:500", 10);
    let res = run_btard(&cfg, quad());
    for byz in [4usize, 5] {
        assert!(
            res.ban_events.iter().any(|b| b.target == byz),
            "peer {byz} not banned: {:?}",
            res.ban_events
        );
    }
    assert!(res.ban_events.iter().all(|b| b.target == 4 || b.target == 5));
}

#[test]
fn ipm_attacker_is_banned() {
    // IPM sends −ε·mean(honest) — a *plausible-looking* gradient, but it
    // does not match any hash-committed honest computation, so a
    // validator recomputing from the public seed catches it.
    let mut cfg = base_cfg(4, vec![3], 120);
    attack(&mut cfg, "ipm:0.6", 5);
    let res = run_btard(&cfg, quad());
    assert!(res.ban_events.iter().any(|b| b.target == 3), "{:?}", res.ban_events);
}

// --- protocol-surface adversaries, end-to-end via `--attack` specs ----------

#[test]
fn equivocator_is_banned_and_training_recovers() {
    let mut cfg = base_cfg(4, vec![2], 100);
    attack(&mut cfg, "equivocate", 2);
    let res = run_btard(&cfg, quad());
    let ev = res.ban_events.iter().find(|b| b.target == 2).expect("equivocator banned");
    assert_eq!(ev.reason, BanReason::Equivocation);
    assert_eq!(ev.step, 2, "caught in the step it first equivocated");
    assert!(res.ban_events.iter().all(|b| b.target == 2), "{:?}", res.ban_events);
    assert!(res.final_metric < 1.0, "honest peers must converge: {}", res.final_metric);
}

#[test]
fn bad_scalar_liar_is_banned_and_training_recovers() {
    // Wrong CenteredClip s_i^j: caught by the owner-side Verification 2
    // recheck (or the Σs alarm), adjudicated by recomputation from the
    // public batch seed.
    let mut cfg = base_cfg(4, vec![2], 100);
    attack(&mut cfg, "bad_scalar", 2);
    let res = run_btard(&cfg, quad());
    let ev = res.ban_events.iter().find(|b| b.target == 2).expect("scalar liar banned");
    assert!(
        matches!(
            ev.reason,
            BanReason::InnerProductMismatch
                | BanReason::AggregationMismatch
                | BanReason::GradientMismatch
        ),
        "{ev:?}"
    );
    assert!(res.ban_events.iter().all(|b| b.target == 2), "{:?}", res.ban_events);
    assert!(res.final_metric < 1.0, "honest peers must converge: {}", res.final_metric);
}

#[test]
fn false_accuser_is_banned_and_training_recovers() {
    // Baseless accusations against honest peers: adjudication recomputes
    // from public seeds, finds the target clean, and the Hammurabi rule
    // bans the accuser. No honest peer may be harmed.
    let mut cfg = base_cfg(4, vec![2], 100);
    attack(&mut cfg, "false_accuse", 2); // prob 1: accuses every active step
    let res = run_btard(&cfg, quad());
    let ev = res.ban_events.iter().find(|b| b.target == 2).expect("false accuser banned");
    assert_eq!(ev.reason, BanReason::FalseAccusation);
    assert!(ev.step >= 2);
    assert!(
        res.ban_events.iter().all(|b| b.target == 2),
        "honest peer banned by a false accusation: {:?}",
        res.ban_events
    );
    assert!(res.final_metric < 1.0, "honest peers must converge: {}", res.final_metric);
}

#[test]
fn mprng_aborter_is_banned_and_training_recovers() {
    // Withholding the reveal after seeing every commitment (the Cleve
    // abort-bias attempt): identified by the combine step, banned, and
    // the round restarts without the offender — no honest casualties.
    let mut cfg = base_cfg(4, vec![3], 60);
    attack(&mut cfg, "mprng_abort", 1);
    let res = run_btard(&cfg, quad());
    let ev = res.ban_events.iter().find(|b| b.target == 3).expect("aborter banned");
    assert_eq!(ev.reason, BanReason::MprngViolation);
    assert_eq!(ev.step, 1);
    assert!(res.ban_events.iter().all(|b| b.target == 3), "{:?}", res.ban_events);
    assert_eq!(res.steps_done, 60, "run must survive the aborted round");
}

#[test]
fn mprng_biaser_is_banned() {
    // Revealing bytes that mismatch the commitment (output steering):
    // commit-before-reveal makes it self-incriminating.
    let mut cfg = base_cfg(4, vec![1], 30);
    attack(&mut cfg, "mprng_bias", 2);
    let res = run_btard(&cfg, quad());
    let ev = res.ban_events.iter().find(|b| b.target == 1).expect("biaser banned");
    assert_eq!(ev.reason, BanReason::MprngViolation);
    assert!(res.ban_events.iter().all(|b| b.target == 1), "{:?}", res.ban_events);
}

#[test]
fn withholding_triggers_mutual_elimination() {
    // Peer 3 refuses peer 1 its gradient part: only peer 1 can see the
    // gap, so the protocol answers with the mutual ELIMINATE trade —
    // exactly one honest casualty per Byzantine (§3.2).
    let mut cfg = base_cfg(4, vec![3], 10);
    attack(&mut cfg, "withhold:1", 0);
    let res = run_btard(&cfg, quad());
    let banned: Vec<usize> = res.ban_events.iter().map(|b| b.target).collect();
    assert!(banned.contains(&3), "{:?}", res.ban_events);
    assert!(banned.contains(&1), "{:?}", res.ban_events);
    assert_eq!(banned.len(), 2, "{:?}", res.ban_events);
    assert!(res.ban_events.iter().all(|b| b.reason == BanReason::Eliminated));
}

#[test]
fn aggregation_corruptor_is_banned() {
    // Shifted CenteredClip output + single-handed Σs cover-up: dodges
    // the cheap checks, but a drawn validator re-deriving the cheater's
    // scalars from the public seed eventually exposes it.
    let mut cfg = base_cfg(4, vec![1], 40);
    attack(&mut cfg, "aggregation:2", 1);
    let res = run_btard(&cfg, quad());
    assert!(
        res.ban_events.iter().any(|b| b.target == 1),
        "aggregation attacker not banned: {:?}",
        res.ban_events
    );
    // Only the attacker is removed.
    assert!(res.ban_events.iter().all(|b| b.target == 1), "{:?}", res.ban_events);
}

#[test]
fn composed_adversary_all_components_answered() {
    // A composite attacking two surfaces at once: the gradient zoo's
    // sign-flip plus commitment equivocation. The equivocation evidence
    // is proven first (same-step broadcast data), and no honest peer is
    // harmed either way.
    let mut cfg = base_cfg(4, vec![3], 80);
    attack(&mut cfg, "sign_flip:1000+equivocate", 3);
    let res = run_btard(&cfg, quad());
    let ev = res.ban_events.iter().find(|b| b.target == 3).expect("composite banned");
    assert!(
        matches!(ev.reason, BanReason::Equivocation | BanReason::GradientMismatch),
        "{ev:?}"
    );
    assert!(res.ban_events.iter().all(|b| b.target == 3), "{:?}", res.ban_events);
    assert!(res.final_metric < 1.0, "honest peers must converge: {}", res.final_metric);
}

// --- a bespoke adversary outside the registry -------------------------------

mod custom {
    use super::*;
    use btard::coordinator::adversary::{Adversary, GradientCtx};

    /// Not in the registry: scales its honest gradient by a constant.
    /// Looks statistically plausible, but no hash-committed honest
    /// computation produces it, so validator recomputation catches it —
    /// proving third-party `Adversary` impls plug into the same loop.
    struct GradientScaler {
        factor: f32,
        start: u64,
    }

    impl Adversary for GradientScaler {
        fn spec(&self) -> String {
            format!("custom_scaler:{}", self.factor)
        }
        fn gradient(&mut self, cx: &GradientCtx) -> Option<Vec<f32>> {
            if cx.step < self.start {
                return None;
            }
            let (_, mut g) = cx.source.loss_and_grad(cx.params, cx.own_seed);
            for v in g.iter_mut() {
                *v *= self.factor;
            }
            Some(g)
        }
    }

    #[test]
    fn out_of_registry_adversary_is_caught() {
        use btard::coordinator::partition::{OwnerMap, PartitionSpec};
        use btard::coordinator::step::{btard_step, Behavior, PeerCtx, ProtocolConfig};
        use btard::net::local::build_cluster;
        use btard::util::rng::Rng;

        let n = 4;
        let steps = 30u64;
        let source = quad();
        let params0 = source.init_params(0);
        let cluster = build_cluster(n, 900, true);
        let mut handles = Vec::new();
        for net in cluster {
            let peer = net.id;
            let source = source.clone();
            let params0 = params0.clone();
            let behavior = if peer == 2 {
                Behavior::Byzantine(Box::new(GradientScaler { factor: 3.0, start: 4 }))
            } else {
                Behavior::Honest
            };
            let h = std::thread::spawn(move || {
                let cfgp = ProtocolConfig {
                    n0: n,
                    tau: TauPolicy::Fixed(2.0),
                    delta_max: 5.0,
                    ..ProtocolConfig::default()
                };
                let r0 = btard::crypto::sha256_parts(&[b"manual", &1u64.to_le_bytes()]);
                let mut ctx = PeerCtx {
                    net: Box::new(net),
                    cfg: cfgp,
                    source,
                    spec: PartitionSpec::new(params0.len(), n),
                    owners: OwnerMap::initial(n),
                    live: (0..n).collect(),
                    membership: btard::coordinator::Membership::default(),
                    ledger: btard::coordinator::BanLedger::new(),
                    equiv: btard::net::gossip::EquivocationTracker::new(),
                    behavior,
                    local_rng: Rng::new(1000 + peer as u64),
                    r_prev: r0,
                    validators: vec![],
                    archive: None,
                    recompute_count: 0,
                };
                let mut params = params0;
                for step in 0..steps {
                    match btard_step(&mut ctx, step, &params) {
                        Ok(out) => {
                            for (p, g) in params.iter_mut().zip(&out.aggregated) {
                                *p -= 0.05 * g;
                            }
                        }
                        Err(_) => break,
                    }
                    if ctx.ledger.is_banned(peer) {
                        break;
                    }
                }
                (peer, ctx.ledger)
            });
            handles.push(h);
        }
        let mut ledger0 = None;
        for h in handles {
            let (peer, ledger) = h.join().expect("peer thread");
            if peer == 0 {
                ledger0 = Some(ledger);
            }
        }
        let ledger = ledger0.unwrap();
        let ev = ledger.events.iter().find(|e| e.target == 2).expect("scaler banned");
        assert_eq!(ev.reason, BanReason::GradientMismatch);
        assert!(ev.step >= 4, "banned before deviating?");
        assert!(ledger.events.iter().all(|e| e.target == 2), "{:?}", ledger.events);
    }
}
