//! Pooled-scheduler integration tests.
//!
//! The pooled execution model multiplexes N logical peers over W
//! workers with a barrier between protocol stages and deterministic
//! drain-mode message ordering. Its contract: a pooled run is
//! bit-identical to the legacy one-OS-thread-per-peer run on the same
//! seed (wall-clock timing fields aside), and cluster sizes far beyond
//! the per-thread model's comfort zone complete on a handful of
//! workers.

use btard::coordinator::adversary::AdversarySpec;
use btard::coordinator::attacks::AttackSchedule;
use btard::coordinator::centered_clip::TauPolicy;
use btard::coordinator::membership::MembershipSchedule;
use btard::coordinator::optimizer::LrSchedule;
use btard::coordinator::training::{
    run_btard_pooled, run_btard_threaded, OptSpec, RunConfig, RunResult,
};
use btard::coordinator::ProtocolConfig;
use btard::model::synthetic::Quadratic;
use btard::model::GradientSource;
use btard::net::NetworkProfile;
use std::sync::Arc;

fn sweep_cfg(n: usize, byz: usize, steps: u64, attack_start: u64) -> RunConfig {
    RunConfig {
        n_peers: n,
        byzantine: ((n - byz)..n).collect(),
        attack: if byz > 0 {
            Some((
                AdversarySpec::parse("sign_flip:1000").unwrap(),
                AttackSchedule::from_step(attack_start),
            ))
        } else {
            None
        },
        steps,
        protocol: ProtocolConfig {
            n0: n,
            tau: TauPolicy::Fixed(1.0),
            m_validators: (n / 8).max(1),
            delta_max: 4.0,
            ..ProtocolConfig::default()
        },
        opt: OptSpec::Sgd {
            schedule: LrSchedule::Constant(0.1),
            momentum: 0.0,
            nesterov: false,
        },
        clip_lambda: None,
        eval_every: 2,
        seed: 7,
        verify_signatures: false,
        gossip_fanout: 8,
        session_mac: false,
        network: NetworkProfile::perfect(),
        churn: MembershipSchedule::empty(),
        admission: Default::default(),
        segments: vec![],
        checkpoint: None,
    }
}

/// Bitwise comparison of everything deterministic in a RunResult (the
/// wall-clock timing fields are the only excluded members).
fn assert_bit_identical(a: &RunResult, b: &RunResult) {
    assert_eq!(a.steps_done, b.steps_done, "steps_done");
    assert_eq!(a.final_params.len(), b.final_params.len(), "param dim");
    for (i, (x, y)) in a.final_params.iter().zip(&b.final_params).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "param {i}: {x} vs {y}");
    }
    assert_eq!(a.final_metric.to_bits(), b.final_metric.to_bits(), "final_metric");
    assert_eq!(a.ban_events, b.ban_events, "ban events");
    assert_eq!(a.recomputes, b.recomputes, "recomputes");
    assert_eq!(a.peer_bytes, b.peer_bytes, "traffic accounting");
    assert_eq!(a.metrics.len(), b.metrics.len(), "metric series length");
    for (ma, mb) in a.metrics.iter().zip(&b.metrics) {
        assert_eq!(ma.step, mb.step);
        assert_eq!(ma.loss.to_bits(), mb.loss.to_bits(), "loss @ step {}", ma.step);
        assert_eq!(ma.metric.to_bits(), mb.metric.to_bits(), "metric @ step {}", ma.step);
        assert_eq!(ma.banned_now, mb.banned_now, "bans @ step {}", ma.step);
    }
}

#[test]
fn pooled_64_peers_on_4_workers_matches_threaded_bit_for_bit() {
    // 8 sign-flippers attack from step 2; validators catch and ban them.
    // Both execution models must agree on every bit of the result.
    let cfg = sweep_cfg(64, 8, 4, 2);
    let src: Arc<dyn GradientSource> = Arc::new(Quadratic::new(1024, 0.1, 2.0, 1.0, 9));
    let threaded = run_btard_threaded(&cfg, src.clone());
    let pooled = run_btard_pooled(&cfg, src, 4);
    assert_eq!(threaded.steps_done, 4);
    assert_bit_identical(&pooled, &threaded);
}

#[test]
fn pooled_honest_run_matches_threaded_bit_for_bit() {
    let cfg = sweep_cfg(16, 0, 6, 0);
    let src: Arc<dyn GradientSource> = Arc::new(Quadratic::new(256, 0.2, 4.0, 0.5, 11));
    let threaded = run_btard_threaded(&cfg, src.clone());
    let pooled = run_btard_pooled(&cfg, src, 3);
    assert!(threaded.ban_events.is_empty());
    assert_bit_identical(&pooled, &threaded);
}

#[test]
fn pooled_worker_count_does_not_change_results() {
    let cfg = sweep_cfg(24, 4, 3, 1);
    let src: Arc<dyn GradientSource> = Arc::new(Quadratic::new(512, 0.1, 2.0, 1.0, 5));
    let w2 = run_btard_pooled(&cfg, src.clone(), 2);
    let w8 = run_btard_pooled(&cfg, src, 8);
    assert_bit_identical(&w2, &w8);
}

#[test]
fn pooled_256_peers_10_steps_sign_flip_completes_on_8_workers() {
    // The scale acceptance run: 256 logical peers — far past what the
    // per-peer-thread model was built for — on an 8-worker pool, with
    // sign-flip attackers live from step 3.
    let cfg = sweep_cfg(256, 32, 10, 3);
    let src: Arc<dyn GradientSource> = Arc::new(Quadratic::new(4096, 0.1, 2.0, 1.0, 13));
    let res = run_btard_pooled(&cfg, src, 8);
    assert_eq!(res.steps_done, 10, "run must complete all 10 steps");
    // Only Byzantine peers (224..256) may be banned, and the attack must
    // not go entirely unpunished.
    assert!(
        res.ban_events.iter().all(|b| b.target >= 224),
        "honest peer banned: {:?}",
        res.ban_events
    );
    assert!(
        !res.ban_events.is_empty(),
        "no sign-flipper was ever caught in 10 steps"
    );
    assert!(res.final_metric.is_finite(), "final metric {}", res.final_metric);
    // Every live peer paid traffic; accounting must cover all 256.
    assert_eq!(res.peer_bytes.len(), 256);
    assert!(res.peer_bytes.iter().all(|&b| b > 0));
}
