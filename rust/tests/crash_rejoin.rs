//! Crash-recovery integration tests: the acceptance proof for the
//! crash/rejoin subsystem.
//!
//! - A schedule with a mid-run crash and rejoin produces **identical
//!   metrics digests** across the threaded model, the pooled scheduler
//!   at several worker counts, and a loopback socket cluster whose
//!   crashed peer's first life actually ends (its transport is torn
//!   down) before a second, `restarted` life re-enters through the
//!   sponsor-snapshot path. The real-SIGKILL variant (separate OS
//!   processes, `kill(9)` delivered by the cluster runner) is covered
//!   by `cluster_cli_survives_a_scheduled_crash_and_restart` below and
//!   by the crash-recovery CI cell.
//! - Periodic checkpointing is **digest-neutral**: enabling it on the
//!   golden-digest scenario changes nothing (checkpoints are recovery
//!   state, never consensus state).
//! - Checkpoints round-trip bit-exactly for both optimizers, and
//!   `resume_into` restores params/optimizer/RNG from the file.

use btard::coordinator::adversary::AdversarySpec;
use btard::coordinator::attacks::{AttackSchedule, CollusionBoard};
use btard::coordinator::centered_clip::TauPolicy;
use btard::coordinator::membership::MembershipSchedule;
use btard::coordinator::optimizer::LrSchedule;
use btard::coordinator::runconfig::WorkloadSpec;
use btard::coordinator::training::{
    peer_main, prepare_source, run_btard_pooled, run_btard_threaded, LifeSpan, OptSpec, RunConfig,
};
use btard::coordinator::ProtocolConfig;
use btard::crypto::Mont;
use btard::harness::{merge_reports, run_digest, PeerReport};
use btard::net::socket::SocketNet;
use btard::net::{
    bind_ephemeral, derive_keypair, NetworkProfile, Roster, RosterEntry, SocketConfig, Transport,
};
use btard::runtime::checkpoint::{latest_checkpoint, Checkpoint, CheckpointConfig};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

/// The cross-model crash scenario: a 6-peer cluster where peer 2
/// crashes at step 3 and rejoins at step 5, while peer 4 sign-flips
/// from step 3. Nesterov momentum is ON so the digest equality also
/// proves the rejoin snapshot's optimizer-state transfer is bit-exact.
fn crash_cfg() -> RunConfig {
    RunConfig {
        n_peers: 6,
        byzantine: vec![4],
        attack: Some((
            AdversarySpec::parse("sign_flip:1000").unwrap(),
            AttackSchedule::from_step(3),
        )),
        steps: 8,
        protocol: ProtocolConfig {
            n0: 6,
            tau: TauPolicy::Fixed(1.0),
            m_validators: 2,
            delta_max: 4.0,
            ..ProtocolConfig::default()
        },
        opt: OptSpec::Sgd {
            schedule: LrSchedule::Constant(0.1),
            momentum: 0.9,
            nesterov: true,
        },
        clip_lambda: None,
        eval_every: 2,
        seed: 7,
        verify_signatures: false,
        gossip_fanout: 8,
        session_mac: false,
        network: NetworkProfile::perfect(),
        churn: MembershipSchedule::parse("crash:2@3,rejoin:2@5").unwrap(),
        admission: Default::default(),
        segments: vec![],
        checkpoint: None,
    }
}

fn quad_workload() -> WorkloadSpec {
    WorkloadSpec::Quadratic { dim: 64, mu: 0.1, l: 2.0, sigma: 1.0, seed: 9 }
}

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("btard_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn crash_rejoin_is_identical_across_exec_models_and_worker_counts() {
    let cfg = crash_cfg();
    let threaded = run_digest(&run_btard_threaded(&cfg, quad_workload().build()));
    let pooled2 = run_digest(&run_btard_pooled(&cfg, quad_workload().build(), 2));
    let pooled4 = run_digest(&run_btard_pooled(&cfg, quad_workload().build(), 4));
    assert_eq!(threaded, pooled2, "threaded vs pooled(2) under crash/rejoin");
    assert_eq!(pooled2, pooled4, "pooled worker count must not matter under crash/rejoin");
    // The rejoiner actually came back: the run completes, and peer 2 is
    // never a ban target (a crash is an excision, not an offence).
    let res = run_btard_pooled(&cfg, quad_workload().build(), 3);
    assert_eq!(res.steps_done, cfg.steps);
    assert!(
        res.ban_events.iter().all(|b| b.target != 2),
        "crashed peer banned: {:?}",
        res.ban_events
    );
}

#[test]
fn checkpointing_is_digest_neutral_on_the_golden_scenario() {
    // The golden-digest scenario (64 peers, 8 sign-flippers, 4 steps),
    // run with and without periodic checkpointing: every deterministic
    // output bit must be identical — the property that lets the golden
    // file stay untouched while checkpointing ships.
    let mut cfg = RunConfig {
        n_peers: 64,
        byzantine: (56..64).collect(),
        attack: Some((
            AdversarySpec::parse("sign_flip:1000").unwrap(),
            AttackSchedule::from_step(2),
        )),
        steps: 4,
        protocol: ProtocolConfig {
            n0: 64,
            tau: TauPolicy::Fixed(1.0),
            m_validators: 8,
            delta_max: 4.0,
            ..ProtocolConfig::default()
        },
        opt: OptSpec::Sgd {
            schedule: LrSchedule::Constant(0.1),
            momentum: 0.0,
            nesterov: false,
        },
        clip_lambda: None,
        eval_every: 2,
        seed: 7,
        verify_signatures: false,
        gossip_fanout: 8,
        session_mac: false,
        network: NetworkProfile::perfect(),
        churn: MembershipSchedule::empty(),
        admission: Default::default(),
        segments: vec![],
        checkpoint: None,
    };
    let src: Arc<dyn btard::model::GradientSource> =
        Arc::new(btard::model::synthetic::Quadratic::new(1024, 0.1, 2.0, 1.0, 9));
    let plain = run_digest(&run_btard_pooled(&cfg, src.clone(), 4));

    let dir = temp_dir("ckpt_neutral");
    cfg.checkpoint = Some(CheckpointConfig { interval: 2, dir: dir.clone(), keep: 1 });
    let checkpointed = run_digest(&run_btard_pooled(&cfg, src, 4));
    assert_eq!(plain, checkpointed, "checkpointing must never move the digest");
    // ... and the neutrality claim is not vacuous: checkpoints were
    // really written.
    assert!(
        latest_checkpoint(&dir, 0).is_some(),
        "no checkpoint written for peer 0 under {}",
        dir.display()
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn checkpoints_round_trip_bit_exactly_for_both_optimizers() {
    for (tag, opt) in [
        (
            "sgd",
            OptSpec::Sgd {
                schedule: LrSchedule::Constant(0.1),
                momentum: 0.9,
                nesterov: true,
            },
        ),
        ("lamb", OptSpec::Lamb { schedule: LrSchedule::Constant(0.01) }),
    ] {
        let dir = temp_dir(&format!("ckpt_rt_{tag}"));
        let mut cfg = RunConfig::quick(4, 4);
        cfg.opt = opt;
        cfg.eval_every = 2;
        cfg.seed = 11;
        cfg.verify_signatures = false;
        cfg.checkpoint = Some(CheckpointConfig { interval: 2, dir: dir.clone(), keep: 2 });
        let src: Arc<dyn btard::model::GradientSource> =
            Arc::new(btard::model::synthetic::Quadratic::new(64, 0.1, 2.0, 1.0, 9));
        let res = run_btard_pooled(&cfg, src, 2);
        assert_eq!(res.steps_done, 4);

        let (steps, path) =
            latest_checkpoint(&dir, 0).unwrap_or_else(|| panic!("{tag}: no checkpoint for 0"));
        assert_eq!(steps, 4, "{tag}: latest checkpoint is the final one");
        let ck = Checkpoint::load(&path).unwrap_or_else(|e| panic!("{tag}: load: {e}"));
        assert_eq!(ck.run_seed, cfg.seed);
        assert_eq!(ck.peer, 0);
        assert_eq!(ck.steps_done, 4);
        // encode() reproduces the on-disk bytes exactly (versioned
        // header + body + digest seal).
        let on_disk = std::fs::read(&path).unwrap();
        assert_eq!(ck.encode(), on_disk, "{tag}: encode/decode must be bit-exact");
        // resume_into restores params and optimizer state; the RNG
        // cursor decodes too.
        let mut params = vec![0.0f32; ck.snapshot.params.len()];
        let mut opt = cfg.opt.build(params.len(), vec![]);
        ck.resume_into(&mut params, opt.as_mut())
            .unwrap_or_else(|e| panic!("{tag}: resume: {e}"));
        assert_eq!(params.len(), ck.snapshot.params.len());
        for (a, b) in params.iter().zip(&ck.snapshot.params) {
            assert_eq!(a.to_bits(), b.to_bits(), "{tag}: params restored bit-exactly");
        }
        assert!(ck.rng().is_some(), "{tag}: RNG cursor must decode");
        std::fs::remove_dir_all(&dir).ok();
    }
}

/// Loopback socket cluster where peer 2's first life really ends at its
/// crash step (transport torn down) and a second, `restarted` life —
/// fresh listener, fresh address published as `addr_2.rejoin`, no
/// founding links — re-enters at the rejoin boundary. The merged digest
/// must equal the in-process runs bit-for-bit.
#[test]
fn socket_cluster_with_a_crashed_and_restarted_peer_matches_in_process() {
    let cfg = RunConfig {
        n_peers: 5,
        byzantine: vec![3],
        attack: Some((
            AdversarySpec::parse("sign_flip:1000").unwrap(),
            AttackSchedule::from_step(2),
        )),
        steps: 6,
        protocol: ProtocolConfig {
            n0: 5,
            tau: TauPolicy::Fixed(1.0),
            m_validators: 1,
            delta_max: 4.0,
            ..ProtocolConfig::default()
        },
        opt: OptSpec::Sgd {
            schedule: LrSchedule::Constant(0.1),
            momentum: 0.0,
            nesterov: false,
        },
        clip_lambda: None,
        eval_every: 2,
        seed: 7,
        verify_signatures: true,
        gossip_fanout: 8,
        session_mac: false,
        network: NetworkProfile::perfect(),
        churn: MembershipSchedule::parse("crash:2@3,rejoin:2@5").unwrap(),
        admission: Default::default(),
        segments: vec![],
        checkpoint: None,
    };
    let workload = quad_workload();

    let threaded = run_digest(&run_btard_threaded(&cfg, workload.build()));
    let pooled = run_digest(&run_btard_pooled(&cfg, workload.build(), 2));
    assert_eq!(threaded, pooled, "in-process execution models must agree first");

    let rejoin_dir = temp_dir("rejoin_addrs");
    let n = cfg.n_peers;
    let mont = Mont::new();
    let mut listeners = Vec::with_capacity(n);
    let mut entries = Vec::with_capacity(n);
    for k in 0..n {
        let (listener, addr) = bind_ephemeral().unwrap();
        entries.push(RosterEntry {
            id: k,
            addr,
            pubkey: derive_keypair(&mont, cfg.seed, k).public,
        });
        listeners.push(listener);
    }
    let roster = Roster { peers: entries };
    let base_scfg = |restarted: bool| SocketConfig {
        gossip_fanout: cfg.gossip_fanout,
        verify_signatures: cfg.verify_signatures,
        connect_timeout: Duration::from_secs(30),
        join_steps: cfg.churn.join_steps(n),
        crash_steps: cfg.churn.crash_steps(n),
        rejoin_steps: cfg.churn.rejoin_steps(n),
        restarted,
        rejoin_addr_dir: Some(rejoin_dir.clone()),
        ..SocketConfig::default()
    };
    let mut handles = Vec::with_capacity(n);
    for (k, listener) in listeners.into_iter().enumerate() {
        let roster = roster.clone();
        let cfg = cfg.clone();
        let workload = workload.clone();
        let scfg = base_scfg(false);
        let scfg_restarted = base_scfg(true);
        let rejoin_dir = rejoin_dir.clone();
        handles.push(std::thread::spawn(move || {
            let mont = Mont::new();
            let secret = derive_keypair(&mont, cfg.seed, k);
            let source = prepare_source(&cfg, workload.build());
            let init_params = source.init_params(cfg.seed);
            if k != 2 {
                let net = SocketNet::connect(listener, &roster, k, secret, &scfg).unwrap();
                let info = net.info().clone();
                let out = peer_main(
                    Box::new(net),
                    cfg.clone(),
                    source,
                    init_params,
                    CollusionBoard::new(),
                    LifeSpan::Whole,
                );
                return PeerReport::from_output(k, out, info.stats.total_bytes(k));
            }
            // Peer 2, first life: run to the crash step, then tear the
            // transport down — to every other peer this is an abrupt
            // link death, not a LEAVE.
            let net = SocketNet::connect(listener, &roster, k, secret, &scfg).unwrap();
            let info1 = net.info().clone();
            let out1 = peer_main(
                Box::new(net),
                cfg.clone(),
                source.clone(),
                init_params.clone(),
                CollusionBoard::new(),
                LifeSpan::UntilCrash,
            );
            let bytes1 = info1.stats.total_bytes(k);
            // Second life: a fresh listener on a fresh port, published
            // where the incumbents will look for it at the rejoin
            // boundary, then the restarted connect path (no founding
            // links — the mesh revives lazily at the boundary).
            let (listener2, addr2) = bind_ephemeral().unwrap();
            btard::util::atomic_write(&rejoin_dir.join("addr_2.rejoin"), &addr2).unwrap();
            let mont = Mont::new();
            let secret = derive_keypair(&mont, cfg.seed, k);
            let net = SocketNet::connect(listener2, &roster, k, secret, &scfg_restarted).unwrap();
            let info2 = net.info().clone();
            let out2 = peer_main(
                Box::new(net),
                cfg.clone(),
                source,
                init_params,
                CollusionBoard::new(),
                LifeSpan::FromRejoin,
            );
            // The two lives' counters sum to what the in-process models
            // (which count the peer cumulatively) record.
            let mut report =
                PeerReport::from_output(k, out2, bytes1 + info2.stats.total_bytes(k));
            report.recomputes += out1.recomputes;
            report
        }));
    }
    let reports: Vec<PeerReport> =
        handles.into_iter().map(|h| h.join().expect("peer thread panicked")).collect();
    let merged = merge_reports(n, reports).unwrap();
    assert_eq!(
        run_digest(&merged),
        threaded,
        "a socket cluster with a crashed-and-restarted peer must reproduce the \
         in-process digest"
    );
    std::fs::remove_dir_all(&rejoin_dir).ok();
}

#[test]
fn cluster_cli_survives_a_scheduled_crash_and_restart() {
    // The real thing, process boundary included: the cluster runner
    // forks 6 peers, peer 2 parks at its crash step and is SIGKILLed,
    // a fresh process rejoins with --restart (warm-starting from its
    // checkpoint), and --verify-inprocess makes the binary fail unless
    // the digest matches the in-process pooled run bit-for-bit. This is
    // the crash-recovery CI cell in miniature.
    let bin = env!("CARGO_BIN_EXE_btard");
    let out = std::env::temp_dir().join(format!("btard_cluster_crash_{}", std::process::id()));
    std::fs::remove_dir_all(&out).ok();
    let ckpt_dir = out.join("ckpt");
    let status = std::process::Command::new(bin)
        .args([
            "cluster",
            "--peers",
            "6",
            "--byzantine",
            "1",
            "--attack",
            "sign_flip:1000",
            "--attack-start",
            "2",
            "--steps",
            "8",
            "--dim",
            "64",
            "--churn",
            "crash:2@4,rejoin:2@6",
            "--checkpoint-interval",
            "2",
            "--checkpoint-dir",
        ])
        .arg(&ckpt_dir)
        .args(["--verify-inprocess", "--out"])
        .arg(&out)
        .status()
        .expect("launching btard cluster");
    assert!(status.success(), "btard cluster with a crash schedule failed");
    let summary = std::fs::read_to_string(out.join("cluster_summary.json")).unwrap();
    // The exit accounting proves the process was really killed and
    // restarted: a "crash" life and a "rejoin" life both appear.
    assert!(summary.contains("\"crash\""), "{summary}");
    assert!(summary.contains("\"rejoin\""), "{summary}");
    assert!(summary.contains("\"whole\""), "{summary}");
    assert!(
        out.join("peer_2.restart.log").exists(),
        "the second life must have its own log"
    );
    // The first life wrote checkpoints the second life could warm-start
    // from.
    assert!(
        latest_checkpoint(&ckpt_dir, 2).is_some(),
        "no checkpoint for the crashed peer under {}",
        ckpt_dir.display()
    );
    std::fs::remove_dir_all(&out).ok();
}
