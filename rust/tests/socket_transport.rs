//! Socket-transport integration tests: the acceptance proof that a
//! BTARD cluster crossing real process/socket boundaries is
//! bit-identical to the in-process run.
//!
//! - A 4-peer in-test socket cluster (loopback TCP, one endpoint per
//!   thread, each with its own per-"process" state: gradient source,
//!   collusion board, traffic stats) whose merged metrics digest equals
//!   both in-process execution models' digests on the same seed.
//! - A true multi-process run through the CLI: `btard cluster
//!   --verify-inprocess` forks `btard peer` subprocesses and fails
//!   unless the digests agree (the same command the blocking
//!   `cluster-smoke` CI job runs at 8 peers).
//! - Mesh-build failure behaviour: a missing peer times the build out
//!   instead of hanging it.
//! - Gossip-overlay acceptance: the same cluster over sparse overlay
//!   links (broadcasts crossing honest relays) reproduces the digest,
//!   delivers equivocation evidence to every honest peer, and survives
//!   a crashed relay on stride redundancy alone.
//!
//! Frame-codec edge cases (split reads, oversized/garbage rejection)
//! live next to the codec in `rust/src/net/socket.rs`; overlay-purity
//! property tests live next to `Overlay::derive` in
//! `rust/src/net/gossip.rs`.

use btard::coordinator::adversary::AdversarySpec;
use btard::coordinator::attacks::{AttackSchedule, CollusionBoard};
use btard::coordinator::centered_clip::TauPolicy;
use btard::coordinator::membership::MembershipSchedule;
use btard::coordinator::optimizer::LrSchedule;
use btard::coordinator::runconfig::WorkloadSpec;
use btard::coordinator::training::{
    peer_main, prepare_source, run_btard_pooled, run_btard_threaded, LifeSpan, OptSpec, RunConfig,
};
use btard::coordinator::ProtocolConfig;
use btard::crypto::Mont;
use btard::harness::{merge_reports, run_digest, PeerReport};
use btard::net::socket::SocketNet;
use btard::net::{
    bind_ephemeral, derive_keypair, slots, MsgClass, NetworkProfile, Roster, RosterEntry,
    SocketConfig, Transport,
};
use std::time::Duration;

/// The fixed scenario: 4 peers, one sign-flipper from step 1, 3 steps,
/// signatures ON (the wire-signature path is the whole point here).
fn socket_cfg() -> RunConfig {
    RunConfig {
        n_peers: 4,
        byzantine: vec![3],
        attack: Some((
            AdversarySpec::parse("sign_flip:1000").unwrap(),
            AttackSchedule::from_step(1),
        )),
        steps: 3,
        protocol: ProtocolConfig {
            n0: 4,
            tau: TauPolicy::Fixed(1.0),
            m_validators: 1,
            delta_max: 4.0,
            ..ProtocolConfig::default()
        },
        opt: OptSpec::Sgd {
            schedule: LrSchedule::Constant(0.1),
            momentum: 0.0,
            nesterov: false,
        },
        clip_lambda: None,
        eval_every: 2,
        seed: 7,
        verify_signatures: true,
        gossip_fanout: 8,
        session_mac: false,
        network: NetworkProfile::perfect(),
        churn: MembershipSchedule::empty(),
        admission: Default::default(),
        segments: vec![],
        checkpoint: None,
    }
}

/// Run the config over a loopback TCP mesh, one endpoint per thread,
/// mirroring separate processes: every peer builds its own source,
/// board and traffic stats, and shares nothing but the roster. With
/// `gossip` set the endpoints keep only their overlay links and every
/// broadcast crosses relays (the same wiring `harness::cluster` uses
/// for `TransportKind::Gossip`).
fn run_socket_cluster(cfg: &RunConfig, workload: &WorkloadSpec, gossip: bool) -> Vec<PeerReport> {
    let n = cfg.n_peers;
    let mont = Mont::new();
    let mut listeners = Vec::with_capacity(n);
    let mut entries = Vec::with_capacity(n);
    for k in 0..n {
        let (listener, addr) = bind_ephemeral().unwrap();
        entries.push(RosterEntry {
            id: k,
            addr,
            pubkey: derive_keypair(&mont, cfg.seed, k).public,
        });
        listeners.push(listener);
    }
    let roster = Roster { peers: entries };
    let mut handles = Vec::with_capacity(n);
    for (k, listener) in listeners.into_iter().enumerate() {
        let roster = roster.clone();
        let cfg = cfg.clone();
        let workload = workload.clone();
        handles.push(std::thread::spawn(move || {
            let mont = Mont::new();
            let secret = derive_keypair(&mont, cfg.seed, k);
            let scfg = SocketConfig {
                gossip,
                gossip_fanout: cfg.gossip_fanout,
                overlay_seed: cfg.seed,
                verify_signatures: cfg.verify_signatures,
                session_mac: cfg.session_mac,
                connect_timeout: Duration::from_secs(30),
                ..SocketConfig::default()
            };
            let net = SocketNet::connect(listener, &roster, k, secret, &scfg).unwrap();
            let info = net.info().clone();
            let source = prepare_source(&cfg, workload.build());
            let init_params = source.init_params(cfg.seed);
            let board = CollusionBoard::new();
            let out =
                peer_main(Box::new(net), cfg.clone(), source, init_params, board, LifeSpan::Whole);
            PeerReport::from_output(k, out, info.stats.total_bytes(k))
        }));
    }
    handles.into_iter().map(|h| h.join().expect("peer thread panicked")).collect()
}

#[test]
fn four_peer_socket_cluster_is_bit_identical_to_in_process_runs() {
    let cfg = socket_cfg();
    let workload = WorkloadSpec::Quadratic { dim: 64, mu: 0.1, l: 2.0, sigma: 1.0, seed: 9 };

    let threaded = run_digest(&run_btard_threaded(&cfg, workload.build()));
    let pooled = run_digest(&run_btard_pooled(&cfg, workload.build(), 2));
    assert_eq!(threaded, pooled, "in-process execution models must agree first");

    let reports = run_socket_cluster(&cfg, &workload, false);
    // Per-peer traffic totals are recorded independently per endpoint;
    // every live peer paid something.
    assert!(reports.iter().all(|r| r.own_bytes > 0), "{reports:?}");
    let merged = merge_reports(cfg.n_peers, reports).unwrap();
    assert_eq!(
        run_digest(&merged),
        threaded,
        "a perfect-link socket cluster must reproduce the in-process digest bit-for-bit"
    );
}

#[test]
fn four_peer_gossip_cluster_is_bit_identical_to_in_process_runs() {
    // The same scenario, but every endpoint keeps only its overlay
    // links: broadcasts reach most peers through relays, yet the
    // protocol plane — and therefore the digest — must not move. This
    // is the transport-independence contract extended to a sparse
    // topology: protocol-plane accounting charges one logical broadcast
    // whatever the dissemination fan-out, and relays carry the origin's
    // signature so delivered envelopes are indistinguishable from
    // direct ones.
    let cfg = socket_cfg();
    let workload = WorkloadSpec::Quadratic { dim: 64, mu: 0.1, l: 2.0, sigma: 1.0, seed: 9 };
    let reference = run_digest(&run_btard_threaded(&cfg, workload.build()));
    let reports = run_socket_cluster(&cfg, &workload, true);
    let merged = merge_reports(cfg.n_peers, reports).unwrap();
    assert_eq!(
        run_digest(&merged),
        reference,
        "a gossip-overlay socket cluster must reproduce the in-process digest bit-for-bit"
    );
}

#[test]
fn gossip_relays_deliver_equivocation_evidence_to_every_honest_peer() {
    // An equivocator broadcasts per-recipient contradictory payloads.
    // Over the overlay those variants travel through honest relays
    // (relay-once per *variant*: the tracker forwards a contradicting
    // digest instead of deduplicating it), so every honest peer must
    // end up holding two signed envelopes for one (step, slot, from)
    // key — transferable ban evidence — and ban the equivocator at the
    // same step the in-process run does.
    let mut cfg = socket_cfg();
    cfg.byzantine = vec![3];
    cfg.attack =
        Some((AdversarySpec::parse("equivocate").unwrap(), AttackSchedule::from_step(1)));
    let workload = WorkloadSpec::Quadratic { dim: 64, mu: 0.1, l: 2.0, sigma: 1.0, seed: 9 };
    let reference = run_btard_threaded(&cfg, workload.build());
    assert!(
        reference.ban_events.iter().any(|b| b.target == 3),
        "scenario must actually ban the equivocator in-process: {:?}",
        reference.ban_events
    );
    let reports = run_socket_cluster(&cfg, &workload, true);
    // Every honest peer independently recorded the identical ban
    // evidence before any merging.
    for r in &reports {
        if r.id != 3 {
            assert_eq!(
                r.ban_events,
                reference.ban_events,
                "peer {} must hold the same ban evidence as the in-process run",
                r.id
            );
        }
    }
    let merged = merge_reports(cfg.n_peers, reports).unwrap();
    assert_eq!(
        run_digest(&merged),
        run_digest(&reference),
        "equivocation through relays must converge to the in-process digest"
    );
}

#[test]
fn cluster_cli_forks_processes_and_matches_the_in_process_digest() {
    // The real thing: N separate OS processes over loopback TCP, driven
    // by the CLI exactly like the cluster-smoke CI job (which runs this
    // at 8 peers with a sign-flip attack). --verify-inprocess makes the
    // binary itself fail on any digest mismatch.
    let bin = env!("CARGO_BIN_EXE_btard");
    let out = std::env::temp_dir().join(format!("btard_cluster_cli_{}", std::process::id()));
    std::fs::remove_dir_all(&out).ok();
    let status = std::process::Command::new(bin)
        .args([
            "cluster",
            "--peers",
            "4",
            "--byzantine",
            "1",
            "--attack",
            "sign_flip:1000",
            "--attack-start",
            "1",
            "--steps",
            "2",
            "--dim",
            "64",
            "--verify-inprocess",
            "--out",
        ])
        .arg(&out)
        .status()
        .expect("launching btard cluster");
    assert!(status.success(), "btard cluster --verify-inprocess failed");
    let summary = std::fs::read_to_string(out.join("cluster_summary.json")).unwrap();
    assert!(summary.contains("\"digest\""), "{summary}");
    let csv = std::fs::read_to_string(out.join("cluster_metrics.csv")).unwrap();
    assert!(csv.lines().count() >= 2, "merged metrics CSV must carry the step series:\n{csv}");
    let roster = std::fs::read_to_string(out.join("roster.json")).unwrap();
    assert!(roster.contains("\"pubkey\""), "{roster}");
    std::fs::remove_dir_all(&out).ok();
}

#[test]
fn gossip_broadcasts_survive_a_crashed_relay() {
    // Crash-robustness of the overlay comes from stride redundancy, not
    // re-derivation: with fanout 2 the 4-peer overlay is the seeded
    // ring with +1 and +2 stride edges, and removing any single node
    // leaves the survivors strongly connected. Peer 3 connects, then
    // drops its endpoint before anyone broadcasts; the three live peers
    // must still deliver every live origin's broadcast to every live
    // peer purely over the remaining relay edges.
    let mont = Mont::new();
    let n = 4;
    let seed = 23;
    let (listeners, addrs): (Vec<_>, Vec<_>) = (0..n).map(|_| bind_ephemeral().unwrap()).unzip();
    let roster = Roster {
        peers: addrs
            .into_iter()
            .enumerate()
            .map(|(k, addr)| RosterEntry {
                id: k,
                addr,
                pubkey: derive_keypair(&mont, seed, k).public,
            })
            .collect(),
    };
    let scfg = SocketConfig {
        gossip: true,
        gossip_fanout: 2,
        overlay_seed: seed,
        connect_timeout: Duration::from_secs(20),
        ..SocketConfig::default()
    };
    let barrier = std::sync::Arc::new(std::sync::Barrier::new(n));
    let handles: Vec<_> = listeners
        .into_iter()
        .enumerate()
        .map(|(k, listener)| {
            let roster = roster.clone();
            let scfg = scfg.clone();
            let barrier = barrier.clone();
            std::thread::spawn(move || {
                let mont = Mont::new();
                let mut net =
                    SocketNet::connect(listener, &roster, k, derive_keypair(&mont, seed, k), &scfg)
                        .unwrap();
                barrier.wait(); // everyone fully meshed
                if k == 3 {
                    drop(net); // the crash: links FIN, relays stop
                    barrier.wait();
                    return None;
                }
                barrier.wait(); // peer 3 is gone before any broadcast
                net.set_timeout(Duration::from_secs(20));
                net.broadcast(2, slots::GRAD_COMMIT, MsgClass::Commitment, vec![k as u8; 5]);
                for from in 0..3 {
                    let env = net
                        .recv_keyed(2, slots::GRAD_COMMIT, &|e| e.from == from)
                        .unwrap_or_else(|e| {
                            panic!("peer {k} missing broadcast from {from} after crash: {e:?}")
                        });
                    assert_eq!(env.payload.to_vec(), vec![from as u8; 5]);
                    assert!(
                        env.verify_with(&Mont::new(), &roster.peers[from].pubkey),
                        "relayed envelopes keep the origin's transferable signature"
                    );
                }
                Some(net)
            })
        })
        .collect();
    let nets: Vec<_> = handles.into_iter().map(|h| h.join().expect("peer thread")).collect();
    drop(nets);
}

#[test]
fn stray_inbound_connections_do_not_kill_the_mesh_build() {
    // A port-scanner / health-probe style connection sends garbage at a
    // peer's listener during the mesh build. Contract: it costs only its
    // own connection — the honest mesh still comes up and carries
    // envelopes (a stray probe must never be a denial of service).
    let mont = Mont::new();
    let (l0, a0) = bind_ephemeral().unwrap();
    let (l1, a1) = bind_ephemeral().unwrap();
    let roster = Roster {
        peers: vec![
            RosterEntry { id: 0, addr: a0.clone(), pubkey: derive_keypair(&mont, 11, 0).public },
            RosterEntry { id: 1, addr: a1, pubkey: derive_keypair(&mont, 11, 1).public },
        ],
    };
    let probe = std::thread::spawn(move || {
        use std::io::Write;
        // Errors ignored on purpose: the probe may race the mesh build
        // finishing and get reset — irrelevant to what's asserted.
        let _ = std::net::TcpStream::connect(&a0).and_then(|mut s| {
            s.write_all(b"GET / HTTP/1.1\r\n\r\n")
        });
    });
    let cfg = SocketConfig { connect_timeout: Duration::from_secs(20), ..Default::default() };
    let r1 = roster.clone();
    let c1 = cfg.clone();
    let t1 = std::thread::spawn(move || {
        let mont = Mont::new();
        let mut net = SocketNet::connect(l1, &r1, 1, derive_keypair(&mont, 11, 1), &c1).unwrap();
        net.send(0, 0, btard::net::slots::GRAD_PART, btard::net::MsgClass::GradientPart, vec![5]);
    });
    let mut net0 = SocketNet::connect(l0, &roster, 0, derive_keypair(&mont, 11, 0), &cfg).unwrap();
    let env = net0.recv_keyed(0, btard::net::slots::GRAD_PART, &|e| e.from == 1).unwrap();
    assert_eq!(env.payload.to_vec(), vec![5]);
    probe.join().unwrap();
    t1.join().unwrap();
}

#[test]
fn mesh_build_times_out_when_a_peer_never_shows_up() {
    // Peer 0 accepts from peer 1, which never starts: the build must
    // fail within the budget, not hang the process.
    let mont = Mont::new();
    let (l0, a0) = bind_ephemeral().unwrap();
    let roster = Roster {
        peers: vec![
            RosterEntry { id: 0, addr: a0, pubkey: derive_keypair(&mont, 3, 0).public },
            RosterEntry {
                id: 1,
                addr: "127.0.0.1:1".to_string(), // nobody listens here
                pubkey: derive_keypair(&mont, 3, 1).public,
            },
        ],
    };
    let scfg = SocketConfig {
        connect_timeout: Duration::from_millis(300),
        ..SocketConfig::default()
    };
    let t0 = std::time::Instant::now();
    let err = SocketNet::connect(l0, &roster, 0, derive_keypair(&mont, 3, 0), &scfg)
        .expect_err("mesh build must fail");
    assert_eq!(err.kind(), std::io::ErrorKind::TimedOut, "{err}");
    assert!(t0.elapsed() < Duration::from_secs(10));
}

#[test]
fn connect_rejects_a_secret_that_does_not_match_the_roster() {
    let mont = Mont::new();
    let (l0, a0) = bind_ephemeral().unwrap();
    let (_l1, a1) = bind_ephemeral().unwrap();
    let roster = Roster {
        peers: vec![
            RosterEntry { id: 0, addr: a0, pubkey: derive_keypair(&mont, 3, 0).public },
            RosterEntry { id: 1, addr: a1, pubkey: derive_keypair(&mont, 3, 1).public },
        ],
    };
    // Wrong run seed ⇒ wrong keypair ⇒ refused before any networking.
    let scfg = SocketConfig::default();
    let err = SocketNet::connect(l0, &roster, 0, derive_keypair(&mont, 99, 0), &scfg)
        .expect_err("mismatched keypair must be refused");
    assert!(err.to_string().contains("does not match"), "{err}");
}
