//! Golden-metrics regression gate for the default (perfect-fabric)
//! execution path.
//!
//! The Transport-seam refactor must never change the numerics of a
//! default run: a fixed 64-peer, sign-flip-attacked, pooled run is
//! reduced to a SHA-256 digest over every deterministic output bit
//! (final params, per-step losses/metrics/bans, ban events, traffic and
//! recompute counters) and compared against a checked-in golden digest.
//!
//! Blessing protocol: on the first run (no golden file yet — e.g. right
//! after this test lands, or after an *intentional* numerics change with
//! `BTARD_BLESS=1`) the digest is written to
//! `rust/tests/golden/perfect64.digest` and the test passes with a
//! notice; commit the file to pin the behaviour. Every later run must
//! reproduce it bit-for-bit.

use btard::coordinator::adversary::AdversarySpec;
use btard::coordinator::attacks::AttackSchedule;
use btard::coordinator::centered_clip::TauPolicy;
use btard::coordinator::membership::MembershipSchedule;
use btard::coordinator::optimizer::LrSchedule;
use btard::coordinator::training::{run_btard_pooled, OptSpec, RunConfig};
use btard::coordinator::ProtocolConfig;
// The digest implementation lives in the library (one implementation
// shared with the multi-process cluster runner, or the two proofs would
// drift): harness::cluster::run_digest.
use btard::harness::run_digest;
use btard::model::synthetic::Quadratic;
use btard::model::GradientSource;
use btard::net::NetworkProfile;
use std::path::PathBuf;
use std::sync::Arc;

#[test]
fn perfect_fabric_64_peer_run_matches_golden_digest() {
    // The fixed scenario: 64 peers, 8 sign-flippers from step 2, 4
    // steps on a 4-worker pool — the same shape the pooled-scheduler
    // bit-identity test pins against the threaded path.
    let cfg = RunConfig {
        n_peers: 64,
        byzantine: (56..64).collect(),
        attack: Some((
            AdversarySpec::parse("sign_flip:1000").unwrap(),
            AttackSchedule::from_step(2),
        )),
        steps: 4,
        protocol: ProtocolConfig {
            n0: 64,
            tau: TauPolicy::Fixed(1.0),
            m_validators: 8,
            delta_max: 4.0,
            ..ProtocolConfig::default()
        },
        opt: OptSpec::Sgd {
            schedule: LrSchedule::Constant(0.1),
            momentum: 0.0,
            nesterov: false,
        },
        clip_lambda: None,
        eval_every: 2,
        seed: 7,
        verify_signatures: false,
        gossip_fanout: 8,
        session_mac: false,
        network: NetworkProfile::perfect(),
        churn: MembershipSchedule::empty(),
        admission: Default::default(),
        segments: vec![],
        checkpoint: None,
    };
    let src: Arc<dyn GradientSource> = Arc::new(Quadratic::new(1024, 0.1, 2.0, 1.0, 9));
    let digest = run_digest(&run_btard_pooled(&cfg, src, 4));

    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("rust")
        .join("tests")
        .join("golden")
        .join("perfect64.digest");
    let bless = std::env::var("BTARD_BLESS").is_ok();
    match std::fs::read_to_string(&path) {
        Ok(want) if !bless => {
            assert_eq!(
                digest,
                want.trim(),
                "default-path numerics changed! If intentional, re-bless with \
                 BTARD_BLESS=1 and commit {}",
                path.display()
            );
        }
        _ => {
            std::fs::create_dir_all(path.parent().unwrap()).expect("create golden dir");
            std::fs::write(&path, &digest).expect("write golden digest");
            eprintln!("golden digest blessed at {}: {digest}", path.display());
        }
    }
}
