//! Regression test for the δ→1/2 saddle of CenteredClip: with exactly
//! half the rows forming a coordinated far cluster, the per-coordinate
//! median start sits on a spurious equilibrium; the warm start from a
//! point inside the honest cluster converges to the bounded fixed point.

use btard::coordinator::centered_clip::{centered_clip, centered_clip_init};
use btard::util::rng::Rng;

fn setup() -> (Vec<Vec<f32>>, Vec<f32>) {
    let mut rng = Rng::new(1);
    let p = 300;
    let mut rows: Vec<Vec<f32>> = (0..7)
        .map(|_| {
            let mut v = vec![0.0f32; p];
            rng.fill_gaussian(&mut v, 0.05);
            v
        })
        .collect();
    let mut u = rng.unit_vector(p);
    for x in u.iter_mut() {
        *x *= 250.0;
    }
    for _ in 0..7 {
        rows.push(u.clone());
    }
    let honest_mean: Vec<f32> = (0..p)
        .map(|j| rows[..7].iter().map(|r| r[j]).sum::<f32>() / 7.0)
        .collect();
    (rows, honest_mean)
}

#[test]
fn warm_start_escapes_half_half_saddle() {
    let (rows, honest_mean) = setup();
    let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
    let warm = centered_clip_init(&refs, 0.1, 500, 1e-6, Some(&honest_mean));
    let norm: f32 = warm.value.iter().map(|x| x * x).sum::<f32>().sqrt();
    // Bounded by the honest-cluster scale (row norms ≈ 0.87, spread-
    // dominated since τ ≪ spread): orders of magnitude under the 125
    // saddle.
    assert!(norm < 3.0, "warm-start norm {norm}");
}

#[test]
fn median_start_documents_the_saddle() {
    // At exactly δ = 1/2 the cold (median) start can stall mid-way — the
    // reason the protocol warm-starts. This is outside the paper's
    // δ ≤ 0.1 guarantee; we pin the behaviour so a future "fix" that
    // silently changes it is noticed.
    let (rows, _) = setup();
    let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
    let cold = centered_clip(&refs, 0.1, 500, 1e-6);
    let norm: f32 = cold.value.iter().map(|x| x * x).sum::<f32>().sqrt();
    assert!(norm > 50.0, "cold-start unexpectedly escaped: {norm}");
}

#[test]
fn honest_majority_cold_start_is_fine() {
    // 8 honest vs 7 byz (the 1-validator case): the median start works.
    let (mut rows, _) = setup();
    let mut rng = Rng::new(9);
    let mut extra = vec![0.0f32; 300];
    rng.fill_gaussian(&mut extra, 0.05);
    rows.insert(0, extra);
    let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
    let cold = centered_clip(&refs, 0.1, 500, 1e-6);
    let norm: f32 = cold.value.iter().map(|x| x * x).sum::<f32>().sqrt();
    assert!(norm < 3.0, "cold-start with honest majority: {norm}");
}
