//! Consensus-admission integration tests: the acceptance proof that the
//! leaderless BFT roster round (JOIN_REQUEST petition → rank-R propose →
//! rank-A vote → rank-B certificate → boundary apply) admits a peer that
//! appears in **no** churn schedule, deterministically and bit-identically
//! across every execution model.
//!
//! - A candidate petitioning at step s is admitted with **identical
//!   digests** across the threaded model, the pooled scheduler at several
//!   worker counts, and a loopback socket cluster (the petition is the
//!   candidate-initiated handshake; its links form lazily like any late
//!   joiner's).
//! - The admission path changes *control traffic only*: the training
//!   math (params, losses, bans) is bit-identical to the equivalent
//!   schedule-mode join.
//! - A Byzantine incumbent voting to reject cannot block an honest
//!   admission below f+1 faults — the run is bit-identical to the clean
//!   run, because a losing vote never enters the training transcript.
//! - A crashed peer is timeout-evicted by vote and its id reclaimed by a
//!   fresh petition (the readmission path), again model-invariantly.
//!
//! Schedule-mode runs dispatch exactly what they always did — pinned by
//! `rust/tests/golden_metrics.rs` (static) and `rust/tests/membership.rs`
//! (scheduled churn).

use btard::coordinator::adversary::AdversarySpec;
use btard::coordinator::attacks::{AttackSchedule, CollusionBoard};
use btard::coordinator::consensus::{AdmissionConfig, AdmissionMode};
use btard::coordinator::membership::MembershipSchedule;
use btard::coordinator::optimizer::LrSchedule;
use btard::coordinator::runconfig::WorkloadSpec;
use btard::coordinator::training::{
    peer_main, prepare_source, run_btard_pooled, run_btard_threaded, LifeSpan, OptSpec, RunConfig,
};
use btard::crypto::Mont;
use btard::harness::{merge_reports, run_digest, PeerReport};
use btard::net::socket::SocketNet;
use btard::net::{bind_ephemeral, derive_keypair, Roster, RosterEntry, SocketConfig, Transport};
use std::time::Duration;

fn quad_workload() -> WorkloadSpec {
    WorkloadSpec::Quadratic { dim: 64, mu: 0.1, l: 2.0, sigma: 1.0, seed: 9 }
}

fn consensus(candidates: &[(usize, u64)]) -> AdmissionConfig {
    AdmissionConfig {
        mode: AdmissionMode::Consensus,
        candidates: candidates.to_vec(),
        ..AdmissionConfig::default()
    }
}

/// The baseline scenario: a 5-id universe where peer 4 holds no schedule
/// slot at all — it petitions the four founders at step 2 and enters
/// through the BFT round. Nesterov momentum is ON (RunConfig::quick), so
/// digest equality also proves the post-commit sponsor snapshot carries
/// bit-exact optimizer state to the admitted peer.
fn petition_cfg() -> RunConfig {
    let mut cfg = RunConfig::quick(5, 5);
    cfg.admission = consensus(&[(4, 2)]);
    cfg.eval_every = 2;
    cfg.seed = 7;
    cfg
}

#[test]
fn consensus_admission_is_identical_across_exec_models_and_worker_counts() {
    let cfg = petition_cfg();
    let threaded = run_btard_threaded(&cfg, quad_workload().build());
    let pooled2 = run_btard_pooled(&cfg, quad_workload().build(), 2);
    let pooled5 = run_btard_pooled(&cfg, quad_workload().build(), 5);
    assert_eq!(threaded.steps_done, cfg.steps, "admission must not end the run early");
    assert!(threaded.peer_bytes[4] > 0, "the admitted candidate participated");
    assert!(threaded.ban_events.is_empty(), "{:?}", threaded.ban_events);
    let d = run_digest(&threaded);
    assert_eq!(d, run_digest(&pooled2), "threaded vs pooled(2) under consensus admission");
    assert_eq!(d, run_digest(&pooled5), "pooled worker count must not matter");
}

#[test]
fn admission_changes_control_traffic_but_not_training_math() {
    // The same roster timeline, reached two ways: a consensus petition
    // at step 2 vs a schedule slot at step 2. The protocol plane differs
    // (petitions, proposals, votes, certificates on the wire) but the
    // training transcript — params, losses, bans — must be bit-identical,
    // because the committed document feeds the very same boundary stages
    // the schedule path runs.
    let cons = run_btard_pooled(&petition_cfg(), quad_workload().build(), 3);
    let mut sched_cfg = petition_cfg();
    sched_cfg.admission = AdmissionConfig::default();
    sched_cfg.churn = MembershipSchedule::parse("join:4@2").unwrap();
    let sched = run_btard_pooled(&sched_cfg, quad_workload().build(), 3);

    assert_eq!(cons.steps_done, sched.steps_done);
    assert_eq!(cons.final_params, sched.final_params, "admission path leaked into training math");
    assert_eq!(cons.final_metric.to_bits(), sched.final_metric.to_bits());
    assert_eq!(cons.metrics.len(), sched.metrics.len());
    for (a, b) in cons.metrics.iter().zip(&sched.metrics) {
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "step {} loss diverged", a.step);
        assert_eq!(a.banned_now, b.banned_now, "step {} ban set diverged", a.step);
    }
    // ...and the round really ran: the agreement messages are extra
    // bytes the schedule path never pays.
    let total = |r: &btard::coordinator::training::RunResult| r.peer_bytes.iter().sum::<u64>();
    assert!(
        total(&cons) > total(&sched),
        "consensus run sent no extra control traffic: {} vs {}",
        total(&cons),
        total(&sched)
    );
}

#[test]
fn byzantine_rejector_below_quorum_cannot_block_admission() {
    // One Byzantine incumbent (of four) votes for the empty document.
    // f = ⌊(4−1)/3⌋ = 1, quorum = 3: the three honest votes certify the
    // admission regardless, and since a losing vote never enters the
    // training transcript the whole run is bit-identical to the clean
    // one — the strongest possible "cannot block" statement.
    let mut byz = petition_cfg();
    byz.byzantine = vec![1];
    byz.attack = Some((
        AdversarySpec::parse("reject_admission").unwrap(),
        AttackSchedule::from_step(0),
    ));
    let clean = run_btard_pooled(&petition_cfg(), quad_workload().build(), 3);
    let attacked = run_btard_pooled(&byz, quad_workload().build(), 3);
    assert!(attacked.peer_bytes[4] > 0, "candidate must still be admitted");
    assert!(attacked.ban_events.is_empty(), "{:?}", attacked.ban_events);
    assert_eq!(
        run_digest(&attacked),
        run_digest(&clean),
        "a sub-quorum rejection must be invisible to the run"
    );
}

#[test]
fn crashed_peer_is_voted_out_and_id_reclaimed_by_fresh_petition() {
    // Peer 3 crashes abruptly at step 2 with no scheduled rejoin (legal
    // only in consensus mode). After evict_after = 2 silent steps the
    // incumbents vote the formal eviction at step 4, returning id 3 to
    // the reclaimable pool; a fresh petition at step 5 re-admits it as a
    // reclamation. Model-invariant, run completes at full length.
    let mut cfg = RunConfig::quick(5, 7);
    cfg.churn = MembershipSchedule::parse("crash:3@2").unwrap();
    cfg.admission = consensus(&[(3, 5)]);
    cfg.eval_every = 3;
    cfg.seed = 11;
    let threaded = run_btard_threaded(&cfg, quad_workload().build());
    let pooled = run_btard_pooled(&cfg, quad_workload().build(), 3);
    assert_eq!(threaded.steps_done, 7, "eviction + readmission must not end the run");
    assert!(
        threaded.ban_events.is_empty(),
        "eviction is a vote, not a ban: {:?}",
        threaded.ban_events
    );
    assert!(threaded.peer_bytes[3] > 0, "the reclaimed peer participated");
    assert_eq!(run_digest(&threaded), run_digest(&pooled), "threaded vs pooled under eviction");

    // Pure-eviction variant: nobody re-petitions, the round still fires
    // (an eviction is roster business even with no candidate), and the
    // remaining four peers finish the run.
    let mut evict_only = RunConfig::quick(5, 6);
    evict_only.churn = MembershipSchedule::parse("crash:3@2").unwrap();
    evict_only.admission = consensus(&[]);
    evict_only.eval_every = 3;
    evict_only.seed = 11;
    let t = run_btard_threaded(&evict_only, quad_workload().build());
    let p = run_btard_pooled(&evict_only, quad_workload().build(), 2);
    assert_eq!(t.steps_done, 6);
    assert_eq!(run_digest(&t), run_digest(&p), "threaded vs pooled, eviction-only round");
}

/// Loopback socket cluster running a consensus admission: one endpoint
/// per thread, each with its own per-"process" state, sharing only the
/// roster. The transport tables come from the *effective* schedule (the
/// consensus-derived timeline), exactly as `btard peer` computes them.
fn run_socket_consensus_cluster(cfg: &RunConfig, workload: &WorkloadSpec) -> Vec<PeerReport> {
    let n = cfg.n_peers;
    let mont = Mont::new();
    let mut listeners = Vec::with_capacity(n);
    let mut entries = Vec::with_capacity(n);
    for k in 0..n {
        let (listener, addr) = bind_ephemeral().unwrap();
        entries.push(RosterEntry {
            id: k,
            addr,
            pubkey: derive_keypair(&mont, cfg.seed, k).public,
        });
        listeners.push(listener);
    }
    let roster = Roster { peers: entries };
    let mut handles = Vec::with_capacity(n);
    for (k, listener) in listeners.into_iter().enumerate() {
        let roster = roster.clone();
        let cfg = cfg.clone();
        let workload = workload.clone();
        handles.push(std::thread::spawn(move || {
            let mont = Mont::new();
            let secret = derive_keypair(&mont, cfg.seed, k);
            let scfg = SocketConfig {
                gossip_fanout: cfg.gossip_fanout,
                verify_signatures: cfg.verify_signatures,
                connect_timeout: Duration::from_secs(30),
                join_steps: cfg.effective_churn().join_steps(cfg.n_peers),
                ..SocketConfig::default()
            };
            let net = SocketNet::connect(listener, &roster, k, secret, &scfg).unwrap();
            let info = net.info().clone();
            let source = prepare_source(&cfg, workload.build());
            let init_params = source.init_params(cfg.seed);
            let board = CollusionBoard::new();
            let out =
                peer_main(Box::new(net), cfg.clone(), source, init_params, board, LifeSpan::Whole);
            PeerReport::from_output(k, out, info.stats.total_bytes(k))
        }));
    }
    handles.into_iter().map(|h| h.join().expect("peer thread panicked")).collect()
}

#[test]
fn socket_cluster_admits_a_petitioning_candidate_bit_identically() {
    // 5-id universe over real loopback TCP, signatures ON: peer 4 holds
    // no roster slot and petitions at step 2. Its JOIN_REQUEST is the
    // first frame it ever sends (links form lazily via epoch-stamped
    // HELLOs), the founders run the R/A/B round over the wire, and the
    // merged socket digest must equal both in-process models' digests
    // bit-for-bit — petitions, proposals, votes and certificates are
    // ordinary signed envelopes to the transport.
    let mut cfg = RunConfig::quick(5, 4);
    cfg.admission = consensus(&[(4, 2)]);
    cfg.opt = OptSpec::Sgd { schedule: LrSchedule::Constant(0.1), momentum: 0.0, nesterov: false };
    cfg.protocol.m_validators = 1;
    cfg.eval_every = 2;
    cfg.seed = 7;
    let workload = quad_workload();

    let threaded = run_digest(&run_btard_threaded(&cfg, workload.build()));
    let pooled = run_digest(&run_btard_pooled(&cfg, workload.build(), 2));
    assert_eq!(threaded, pooled, "in-process execution models must agree first");

    let reports = run_socket_consensus_cluster(&cfg, &workload);
    assert!(reports[4].own_bytes > 0, "{reports:?}");
    let merged = merge_reports(cfg.n_peers, reports).unwrap();
    assert_eq!(
        run_digest(&merged),
        threaded,
        "a socket cluster admitting a petitioner must reproduce the in-process digest"
    );
}
