//! Integration tests over the PJRT runtime + AOT artifacts: the three
//! layers compose (L1 Pallas kernel → L2 JAX model → L3 Rust executor).
//!
//! These tests require `make artifacts`; they self-skip (with a loud
//! message) when the artifacts directory is missing so `cargo test`
//! stays runnable on a fresh checkout.

use btard::coordinator::centered_clip::centered_clip;
use btard::data::synth_text::SynthText;
use btard::data::synth_vision::SynthVision;
use btard::model::pjrt_model::{PjrtData, PjrtModel};
use btard::model::GradientSource;
use btard::runtime::PjrtRuntime;
use btard::util::rng::Rng;
use std::sync::Arc;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts/ (run `make artifacts` first)");
        None
    }
}

#[test]
fn vision_artifact_runs_and_is_deterministic() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::load_subset(&dir, &["vision_mlp"]).expect("load artifact");
    let meta = rt.manifest.get("vision_mlp").unwrap().clone();
    let ds = Arc::new(SynthVision::new(0, 64, 10));
    let model = PjrtModel::new(rt.handle.clone(), meta, PjrtData::Vision(ds)).unwrap();
    let params = model.init_params(1);
    let (loss, grad) = model.loss_and_grad(&params, 42);
    assert!(loss.is_finite() && loss > 0.0, "loss {loss}");
    assert_eq!(grad.len(), model.param_dim);
    assert!(grad.iter().any(|&g| g != 0.0));
    // Bitwise determinism — the property the hash-based protocol needs.
    let (loss2, grad2) = model.loss_and_grad(&params, 42);
    assert_eq!(loss.to_bits(), loss2.to_bits());
    assert!(grad.iter().zip(&grad2).all(|(a, b)| a.to_bits() == b.to_bits()));
    // Different seed → different gradient.
    let (_, grad3) = model.loss_and_grad(&params, 43);
    assert_ne!(grad, grad3);
}

#[test]
fn vision_artifact_grad_matches_finite_differences() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::load_subset(&dir, &["vision_mlp"]).expect("load");
    let meta = rt.manifest.get("vision_mlp").unwrap().clone();
    let ds = Arc::new(SynthVision::new(3, 64, 10));
    let model = PjrtModel::new(rt.handle.clone(), meta, PjrtData::Vision(ds)).unwrap();
    let params = model.init_params(5);
    let (_, grad) = model.loss_and_grad(&params, 7);
    let eps = 1e-2f32;
    for c in [0usize, 100, 2000, model.param_dim - 1] {
        let mut pp = params.clone();
        pp[c] += eps;
        let (lp, _) = model.loss_and_grad(&pp, 7);
        pp[c] -= 2.0 * eps;
        let (lm, _) = model.loss_and_grad(&pp, 7);
        let num = (lp - lm) / (2.0 * eps);
        assert!(
            (num - grad[c]).abs() < 5e-2 * num.abs().max(grad[c].abs()).max(0.05),
            "coord {c}: numeric {num} vs analytic {}",
            grad[c]
        );
    }
}

#[test]
fn lm_artifact_trains() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::load_subset(&dir, &["lm_small"]).expect("load");
    let meta = rt.manifest.get("lm_small").unwrap().clone();
    let ds = Arc::new(SynthText::new(1, 100_000));
    let model = PjrtModel::new(rt.handle.clone(), meta, PjrtData::Text(ds)).unwrap();
    let mut params = model.init_params(0);
    let (l0, _) = model.loss_and_grad(&params, 0);
    // Initial loss near log(64) ≈ 4.16 for a near-uniform model.
    assert!((l0 - 64f32.ln()).abs() < 0.8, "initial loss {l0}");
    for s in 0..30 {
        let (_, g) = model.loss_and_grad(&params, s);
        for (p, gi) in params.iter_mut().zip(&g) {
            *p -= 0.5 * gi;
        }
    }
    let (l1, _) = model.loss_and_grad(&params, 1000);
    assert!(l1 < l0 - 0.2, "loss did not improve: {l0} -> {l1}");
}

#[test]
fn clip_artifact_matches_rust_clip() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::load_subset(&dir, &["centered_clip_16x4096"]).expect("load");
    let meta = rt.manifest.get("centered_clip_16x4096").unwrap().clone();
    let (n, p) = (meta.attr_usize("n").unwrap(), meta.attr_usize("p").unwrap());
    let iters = meta.attr_usize("iters").unwrap();
    let mut rng = Rng::new(9);
    let rows: Vec<Vec<f32>> = (0..n)
        .map(|i| {
            let mut v = vec![0.0f32; p];
            rng.fill_gaussian(&mut v, 1.0);
            if i >= n - 3 {
                // a few adversarial rows
                for x in v.iter_mut() {
                    *x += 50.0;
                }
            }
            v
        })
        .collect();
    let tau = 2.0f32;
    // Artifact path
    let mut g_flat = Vec::with_capacity(n * p);
    for r in &rows {
        g_flat.extend_from_slice(r);
    }
    let mask = vec![1.0f32; n];
    let out = rt
        .handle
        .run(
            "centered_clip_16x4096",
            vec![(g_flat, vec![n, p]), (mask, vec![n]), (vec![tau], vec![1])],
        )
        .expect("run clip artifact");
    let artifact_v = &out[0];
    // Rust path: same iteration count, no early stop.
    let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
    let rust_v = centered_clip(&refs, tau, iters, 0.0).value;
    assert_eq!(artifact_v.len(), rust_v.len());
    let mut max_err = 0.0f32;
    for (a, b) in artifact_v.iter().zip(&rust_v) {
        max_err = max_err.max((a - b).abs());
    }
    assert!(max_err < 1e-3, "artifact vs rust clip max err {max_err}");
}

#[test]
fn label_flip_gradient_differs() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::load_subset(&dir, &["vision_mlp"]).expect("load");
    let meta = rt.manifest.get("vision_mlp").unwrap().clone();
    let ds = Arc::new(SynthVision::new(4, 64, 10));
    let model = PjrtModel::new(rt.handle.clone(), meta, PjrtData::Vision(ds)).unwrap();
    let params = model.init_params(2);
    let (_, honest) = model.loss_and_grad(&params, 5);
    let (_, flipped) = model.loss_and_grad_label_flipped(&params, 5).unwrap();
    assert_ne!(honest, flipped);
}
