//! Regression test for the adjudication-storm bug: an honest 16-peer
//! step at d=65k must not trigger Σs false alarms (each alarm costs every
//! peer an O(n) gradient recompute; the bug made 4 steps take 230 s with
//! 68k recomputations — fixed by a relative clip tolerance that respects
//! the constant-velocity warm-start walk and a Σs tolerance that covers
//! fixed-point truncation).

#[test]
fn honest_large_d_step_has_no_recompute_storm() {
    use btard::coordinator::optimizer::LrSchedule;
    use btard::coordinator::training::{run_btard, OptSpec, RunConfig};
    use btard::model::synthetic::Quadratic;
    use std::sync::Arc;
    let src: Arc<dyn btard::model::GradientSource> =
        Arc::new(Quadratic::new(65_536, 0.1, 2.0, 1.0, 5));
    let mut cfg = RunConfig::quick(16, 4);
    cfg.verify_signatures = false;
    cfg.opt = OptSpec::Sgd {
        schedule: LrSchedule::Constant(0.05),
        momentum: 0.0,
        nesterov: false,
    };
    cfg.eval_every = 1000;
    let t0 = std::time::Instant::now();
    let res = run_btard(&cfg, src);
    eprintln!(
        "4 steps in {:.1}s, recomputes={}, bans={}",
        t0.elapsed().as_secs_f64(),
        res.recomputes,
        res.ban_events.len()
    );
    assert!(res.ban_events.is_empty());
    // Budget: validators only (≈ m per step) plus slack.
    assert!(res.recomputes < 50, "recompute storm: {}", res.recomputes);
}
