//! Cross-module property tests: invariants that span subsystems
//! (deterministic replay, aggregation bounds, codec/crypto interplay),
//! run through the in-repo property harness.

use btard::coordinator::aggregators::{coord_median, geo_median, mean, trimmed_mean};
use btard::coordinator::centered_clip::{centered_clip, fixed_point_residual};
use btard::coordinator::messages::{Accusation, BanReason, GradCommit, VerifyScalars};
use btard::coordinator::optimizer::{clip_global_norm, LrSchedule};
use btard::coordinator::partition::PartitionSpec;
use btard::crypto::{keygen, sha256_f32, sign, verify, Mont};
use btard::mprng::{combine, MprngOutcome, MprngRound};
use btard::util::prop::{arb_vec, prop_check};
use btard::util::rng::{l2_norm, Rng};

#[test]
fn aggregation_translation_equivariance() {
    // All aggregators commute with translation: agg(x+c) = agg(x)+c.
    prop_check("translation equivariance", |rng, _| {
        let n = 3 + rng.below_usize(6);
        let p = 1 + rng.below_usize(40);
        let rows: Vec<Vec<f32>> = (0..n).map(|_| arb_vec(rng, p, 1.0)).collect();
        let shift: Vec<f32> = arb_vec(rng, p, 0.5);
        let shifted: Vec<Vec<f32>> = rows
            .iter()
            .map(|r| r.iter().zip(&shift).map(|(a, b)| a + b).collect())
            .collect();
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let srefs: Vec<&[f32]> = shifted.iter().map(|r| r.as_slice()).collect();
        let a = mean(&refs);
        let b = mean(&srefs);
        for j in 0..p {
            assert!((a[j] + shift[j] - b[j]).abs() < 1e-3 * (1.0 + a[j].abs() + shift[j].abs()));
        }
        let a = coord_median(&refs);
        let b = coord_median(&srefs);
        for j in 0..p {
            assert!((a[j] + shift[j] - b[j]).abs() < 1e-3 * (1.0 + a[j].abs() + shift[j].abs()));
        }
    });
}

#[test]
fn clip_output_within_row_hull_bounds() {
    // The clip output never leaves the coordinate-wise [min, max] hull of
    // the rows (each update is a convex-ish combination of pulls toward
    // rows).
    prop_check("clip hull", |rng, _| {
        let n = 3 + rng.below_usize(6);
        let p = 1 + rng.below_usize(30);
        let rows: Vec<Vec<f32>> = (0..n).map(|_| arb_vec(rng, p, 1.0)).collect();
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let out = centered_clip(&refs, 0.5, 200, 1e-6).value;
        for j in 0..p {
            let lo = rows.iter().map(|r| r[j]).fold(f32::INFINITY, f32::min);
            let hi = rows.iter().map(|r| r[j]).fold(f32::NEG_INFINITY, f32::max);
            let slack = 1e-3 * (1.0 + hi.abs().max(lo.abs()));
            assert!(out[j] >= lo - slack && out[j] <= hi + slack, "coord {j}");
        }
    });
}

#[test]
fn clip_residual_decreases_with_iterations() {
    prop_check("residual monotone-ish", |rng, _| {
        let rows: Vec<Vec<f32>> = (0..8).map(|_| arb_vec(rng, 24, 1.0)).collect();
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let early = centered_clip(&refs, 1.0, 2, 0.0).value;
        let late = centered_clip(&refs, 1.0, 200, 0.0).value;
        let r_early = fixed_point_residual(&refs, &early, 1.0);
        let r_late = fixed_point_residual(&refs, &late, 1.0);
        assert!(r_late <= r_early + 1e-4, "{r_early} -> {r_late}");
    });
}

#[test]
fn trimmed_mean_between_min_and_max() {
    prop_check("trimmed mean bounds", |rng, _| {
        let n = 5 + rng.below_usize(8);
        let p = 1 + rng.below_usize(20);
        let trim = rng.below_usize((n - 1) / 2);
        let rows: Vec<Vec<f32>> = (0..n).map(|_| arb_vec(rng, p, 2.0)).collect();
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let out = trimmed_mean(&refs, trim);
        for j in 0..p {
            let lo = rows.iter().map(|r| r[j]).fold(f32::INFINITY, f32::min);
            let hi = rows.iter().map(|r| r[j]).fold(f32::NEG_INFINITY, f32::max);
            assert!(out[j] >= lo - 1e-4 && out[j] <= hi + 1e-4);
        }
    });
}

#[test]
fn geo_median_minimizes_vs_perturbations() {
    // The Weiszfeld output should (weakly) beat nearby perturbations on
    // the sum-of-distances objective.
    let mut rng = Rng::new(5);
    let rows: Vec<Vec<f32>> = (0..9).map(|_| arb_vec(&mut rng, 16, 1.0)).collect();
    let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
    let gm = geo_median(&refs, 500, 1e-9);
    let cost = |v: &[f32]| -> f64 {
        rows.iter()
            .map(|r| {
                r.iter()
                    .zip(v)
                    .map(|(a, b)| ((a - b) as f64).powi(2))
                    .sum::<f64>()
                    .sqrt()
            })
            .sum()
    };
    let c0 = cost(&gm);
    for k in 0..20 {
        let mut v = gm.clone();
        let mut prng = Rng::new(100 + k);
        for x in v.iter_mut() {
            *x += prng.gaussian_f32() * 0.05;
        }
        assert!(cost(&v) >= c0 - 1e-6, "perturbation improved the objective");
    }
}

#[test]
fn partition_hash_stability_under_split() {
    // Hashing a part then hashing the merged whole is consistent with
    // hashing slices of the original vector — the commitment scheme's
    // assumption.
    prop_check("split hashing", |rng, _| {
        let n = 2 + rng.below_usize(8);
        let d = n + rng.below_usize(500);
        let v = arb_vec(rng, d, 1.0);
        let spec = PartitionSpec::new(d, n);
        for j in 0..n {
            let h1 = sha256_f32(spec.slice(&v, j));
            let h2 = sha256_f32(&v[spec.range(j)]);
            assert_eq!(h1, h2);
        }
    });
}

#[test]
fn codec_fuzz_never_panics() {
    // Arbitrary bytes through every decoder: must return None/Some, never
    // panic (malicious peers control these bytes).
    prop_check("decoder fuzz", |rng, _| {
        let len = rng.below_usize(300);
        let bytes: Vec<u8> = (0..len).map(|_| rng.next_u32() as u8).collect();
        let _ = GradCommit::decode(&bytes);
        let _ = VerifyScalars::decode(&bytes);
        let _ = Accusation::decode(&bytes);
        let _ = btard::mprng::parse_reveal(&bytes);
    });
}

#[test]
fn signature_unforgeability_smoke() {
    // Random signature bytes never verify (2^-something, but the point is
    // the code path rejects garbage without panicking).
    let mont = Mont::new();
    let sk = keygen(&mont, 1);
    prop_check("garbage signatures rejected", |rng, _| {
        let mut sig = sign(&mont, &sk, b"legit");
        // Flip random bits.
        sig.s[rng.below_usize(32)] ^= 1 << rng.below_usize(8) as u8;
        assert!(!verify(&mont, &sk.public, b"legit", &sig));
    });
}

#[test]
fn mprng_output_bits_look_uniform() {
    // XOR of honest randomness: quick frequency sanity over many rounds.
    let mut ones = 0u64;
    let mut total = 0u64;
    for round_seed in 0..200u64 {
        let n = 4;
        let rounds: Vec<MprngRound> = (0..n)
            .map(|p| MprngRound::new(p, &mut Rng::new(round_seed * 17 + p as u64)))
            .collect();
        let live: Vec<usize> = (0..n).collect();
        let cs: Vec<_> = rounds.iter().map(|r| Some(r.commitment())).collect();
        let rs: Vec<_> = rounds.iter().map(|r| Some(r.reveal())).collect();
        if let MprngOutcome::Ok(out) = combine(&live, &cs, &rs) {
            for b in out {
                ones += b.count_ones() as u64;
                total += 8;
            }
        }
    }
    let frac = ones as f64 / total as f64;
    assert!((frac - 0.5).abs() < 0.02, "bit frequency {frac}");
}

#[test]
fn lr_schedules_are_positive_and_bounded() {
    prop_check("lr schedule bounds", |rng, _| {
        let base = 0.01 + rng.next_f32();
        let schedules = [
            LrSchedule::Constant(base),
            LrSchedule::Cosine { base, floor: base * 0.1, total_steps: 100 },
            LrSchedule::Warmup { base, warmup: 10 },
        ];
        for s in schedules {
            for step in [0u64, 1, 9, 10, 50, 100, 1000] {
                let lr = s.lr(step);
                assert!(lr > 0.0 && lr <= base * 1.0001, "{s:?} step {step} lr {lr}");
            }
        }
    });
}

#[test]
fn grad_clip_idempotent() {
    prop_check("clip idempotent", |rng, _| {
        let mut g = arb_vec(rng, 64, 10.0);
        let max = 1.0 + rng.next_f32() * 5.0;
        clip_global_norm(&mut g, max);
        let n1 = l2_norm(&g);
        let before = g.clone();
        clip_global_norm(&mut g, max);
        assert!(l2_norm(&g) <= max * 1.0001);
        if n1 <= max {
            assert_eq!(g, before); // second clip is a no-op
        }
    });
}

#[test]
fn ban_reasons_roundtrip_through_accusations() {
    for reason in [
        BanReason::GradientMismatch,
        BanReason::NormMismatch,
        BanReason::InnerProductMismatch,
        BanReason::AggregationMismatch,
        BanReason::Equivocation,
        BanReason::FalseAccusation,
        BanReason::Eliminated,
        BanReason::MprngViolation,
    ] {
        let a = Accusation { target: 3, reason, part: 1 };
        assert_eq!(Accusation::decode(&a.encode()), Some(a));
    }
}
