//! Transport-seam integration tests: the seeded network-fault simulator
//! (`SimNet`) must be deterministic across worker counts, and the BTARD
//! protocol must respond to injected faults with exactly the paper's
//! machinery — mutual elimination for timed-out p2p counterparts, cheap
//! `Proven` MPRNG-abort bans for blacked-out peers — never by banning an
//! uninvolved honest peer.

use btard::coordinator::centered_clip::TauPolicy;
use btard::coordinator::messages::BanReason;
use btard::coordinator::optimizer::LrSchedule;
use btard::coordinator::training::{run_btard_pooled, OptSpec, RunConfig, RunResult};
use btard::model::synthetic::Quadratic;
use btard::model::GradientSource;
use btard::net::NetworkProfile;
use std::sync::Arc;

fn net_cfg(n: usize, steps: u64, network: NetworkProfile) -> RunConfig {
    let mut cfg = RunConfig::quick(n, steps);
    cfg.protocol.tau = TauPolicy::Fixed(2.0);
    cfg.protocol.delta_max = 5.0;
    cfg.opt = OptSpec::Sgd {
        schedule: LrSchedule::Constant(0.3),
        momentum: 0.0,
        nesterov: false,
    };
    cfg.eval_every = 2;
    cfg.seed = 7;
    cfg.verify_signatures = false;
    cfg.network = network;
    cfg
}

/// Bitwise comparison of everything deterministic in a RunResult,
/// including the network-fault counters (wall-clock timing fields are
/// the only excluded members).
fn assert_bit_identical(a: &RunResult, b: &RunResult) {
    assert_eq!(a.steps_done, b.steps_done, "steps_done");
    for (i, (x, y)) in a.final_params.iter().zip(&b.final_params).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "param {i}: {x} vs {y}");
    }
    assert_eq!(a.final_metric.to_bits(), b.final_metric.to_bits(), "final_metric");
    assert_eq!(a.ban_events, b.ban_events, "ban events");
    assert_eq!(a.recomputes, b.recomputes, "recomputes");
    assert_eq!(a.peer_bytes, b.peer_bytes, "traffic accounting");
    assert_eq!(a.net_faults, b.net_faults, "fault accounting");
    assert_eq!(a.metrics.len(), b.metrics.len(), "metric series length");
    for (ma, mb) in a.metrics.iter().zip(&b.metrics) {
        assert_eq!(ma.step, mb.step);
        assert_eq!(ma.loss.to_bits(), mb.loss.to_bits(), "loss @ step {}", ma.step);
        assert_eq!(ma.metric.to_bits(), mb.metric.to_bits(), "metric @ step {}", ma.step);
        assert_eq!(ma.banned_now, mb.banned_now, "bans @ step {}", ma.step);
    }
}

#[test]
fn lossy_simnet_is_bit_identical_across_worker_counts() {
    // Same seed + same profile ⇒ identical fault schedule, delivery
    // order, ban sequence and final metrics, no matter how the logical
    // peers are multiplexed over workers.
    let cfg = net_cfg(24, 4, NetworkProfile::from_name("lossy").unwrap());
    let src: Arc<dyn GradientSource> = Arc::new(Quadratic::new(512, 0.1, 2.0, 1.0, 5));
    let w2 = run_btard_pooled(&cfg, src.clone(), 2);
    let w7 = run_btard_pooled(&cfg, src, 7);
    assert_bit_identical(&w2, &w7);
    // The 5%-loss fabric must actually have exercised the retransmit
    // path: thousands of p2p transmissions at n=24 make zero retries a
    // statistical impossibility (and the schedule is seed-pinned).
    let totals: u64 = w2.net_faults.iter().map(|f| f.retransmits).sum();
    assert!(totals > 0, "lossy profile never retransmitted");
    assert_eq!(w2.net_faults.len(), 24);
}

#[test]
fn dead_link_triggers_one_mutual_elimination_and_training_converges() {
    // A permanently broken directed link 3 → 5: owner 5 never receives
    // contributor 3's gradient part, observes the timeout, and the pair
    // is mutually eliminated at step 0 — the protocol's tit-for-tat cost
    // for unattributable faults. Nobody else may be punished (the Σs
    // alarm this raises is adjudicated against the owner's broadcast
    // ELIMINATE record and acquitted), and training converges with the
    // remaining 6 peers.
    let mut profile = NetworkProfile::perfect();
    profile.name = "deadlink".to_string();
    profile.faulty_links = vec![(3, 5)];
    let cfg = net_cfg(8, 120, profile);
    let src: Arc<dyn GradientSource> = Arc::new(Quadratic::new(64, 0.2, 4.0, 0.5, 11));
    let res = run_btard_pooled(&cfg, src, 4);
    assert_eq!(res.steps_done, 120);
    assert!(!res.ban_events.is_empty(), "dead link must cost the pair");
    for ev in &res.ban_events {
        assert_eq!(ev.reason, BanReason::Eliminated, "{ev:?}");
        assert!(
            [3, 5].contains(&ev.target) && [3, 5].contains(&ev.by),
            "ban outside the faulted pair: {ev:?}"
        );
        assert_eq!(ev.step, 0, "{ev:?}");
    }
    let banned: Vec<_> = res.ban_events.iter().map(|e| e.target).collect();
    assert!(banned.contains(&3) && banned.contains(&5));
    assert!(res.final_metric < 1.0, "no convergence after eliminations: {}", res.final_metric);
}

#[test]
fn blackout_peers_banned_via_mprng_proof_without_honest_casualties() {
    // Peers 2 and 3 black out for steps [1, 3): all their outgoing
    // traffic is dropped. Missing MPRNG commitments are a *proven*
    // offence (the commit–reveal round identifies aborters), and proven
    // bans process before eliminations in the canonical order — so the
    // blacked-out peers are removed without the mutual-elimination tax
    // costing any honest peer.
    let mut profile = NetworkProfile::perfect();
    profile.name = "blackout".to_string();
    profile.partition_peers = vec![2, 3];
    profile.partition_start = 1;
    profile.partition_end = 3;
    let cfg = net_cfg(8, 6, profile.clone());
    let src: Arc<dyn GradientSource> = Arc::new(Quadratic::new(64, 0.2, 4.0, 0.5, 11));
    let res = run_btard_pooled(&cfg, src.clone(), 3);
    assert_eq!(res.steps_done, 6);
    assert_eq!(res.ban_events.len(), 2, "{:?}", res.ban_events);
    for ev in &res.ban_events {
        assert!([2, 3].contains(&ev.target), "honest peer banned: {ev:?}");
        assert_eq!(ev.step, 1, "{ev:?}");
        assert!(
            matches!(ev.reason, BanReason::MprngViolation | BanReason::AggregationMismatch),
            "{ev:?}"
        );
    }
    assert!(res.final_metric.is_finite());
    // The same partitioned run is reproducible across worker counts.
    let cfg2 = net_cfg(8, 6, profile);
    let res2 = run_btard_pooled(&cfg2, src, 5);
    assert_bit_identical(&res, &res2);
}
