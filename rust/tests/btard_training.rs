//! End-to-end training behaviour: BTARD matches the no-attack baseline,
//! recovers from attacks after bans, and its communication cost follows
//! the paper's O(d + n²) claim.

use btard::coordinator::adversary::AdversarySpec;
use btard::coordinator::attacks::AttackSchedule;
use btard::coordinator::centered_clip::TauPolicy;
use btard::coordinator::optimizer::LrSchedule;
use btard::coordinator::training::{
    run_btard, run_btard_pooled, run_ps, OptSpec, PsConfig, RunConfig,
};
use btard::coordinator::{Aggregator, ProtocolConfig};
use btard::data::synth_vision::SynthVision;
use btard::model::mlp::MlpModel;
use btard::model::synthetic::Quadratic;
use btard::model::GradientSource;
use std::sync::Arc;

fn quad(dim: usize) -> Arc<dyn GradientSource> {
    Arc::new(Quadratic::new(dim, 0.2, 4.0, 0.5, 11))
}

fn cfg(n: usize, steps: u64, dim_src: &Arc<dyn GradientSource>) -> RunConfig {
    let _ = dim_src;
    let mut cfg = RunConfig::quick(n, steps);
    cfg.protocol.tau = TauPolicy::Fixed(2.0);
    cfg.protocol.delta_max = 5.0;
    cfg.opt = OptSpec::Sgd {
        schedule: LrSchedule::Constant(0.3),
        momentum: 0.0,
        nesterov: false,
    };
    cfg
}

#[test]
fn btard_matches_ps_mean_without_attack() {
    let src = quad(64);
    let c = cfg(4, 150, &src);
    let btard = run_btard(&c, src.clone());
    let ps = run_ps(
        &PsConfig {
            n_peers: 4,
            byzantine: vec![],
            attack: None,
            aggregator: Aggregator::Mean,
            tau: 2.0,
            steps: 150,
            opt: c.opt.clone(),
            eval_every: 20,
            seed: 0,
        },
        src,
    );
    assert!(btard.final_metric < 0.3, "btard {}", btard.final_metric);
    assert!(ps.final_metric < 0.3, "ps {}", ps.final_metric);
    // Same ballpark (validators exclude one gradient per step, so exact
    // equality is not expected).
    assert!(btard.final_metric < ps.final_metric * 10.0 + 0.1);
}

#[test]
fn mlp_recovers_accuracy_after_attack_quick() {
    // Scaled-down stand-in for the #[ignore]d full Fig. 3 run below so
    // the accuracy-recovery-after-attack claim stays in default CI:
    // signatures off, fewer steps, a conservative accuracy floor (10
    // classes ⇒ chance is 0.1). Pinned to the pooled scheduler with a
    // fixed worker count so the tier-1 run exercises the default
    // execution model regardless of the BTARD_EXEC environment.
    let ds = Arc::new(SynthVision::new(1, 32, 10));
    let model: Arc<dyn GradientSource> = Arc::new(MlpModel::new(ds, 24, 8));
    let mut c = RunConfig::quick(8, 250);
    c.byzantine = vec![5, 6, 7];
    c.attack = Some((
        AdversarySpec::parse("sign_flip:1000").unwrap(),
        AttackSchedule::from_step(30),
    ));
    c.protocol.tau = TauPolicy::Fixed(1.0);
    c.protocol.delta_max = 3.0;
    c.opt = OptSpec::Sgd {
        schedule: LrSchedule::Constant(0.12),
        momentum: 0.9,
        nesterov: true,
    };
    c.eval_every = 25;
    c.verify_signatures = false;
    let res = run_btard_pooled(&c, model, 4);
    for byz in [5usize, 6, 7] {
        assert!(
            res.ban_events.iter().any(|b| b.target == byz),
            "byz {byz} unbanned: {:?}",
            res.ban_events
        );
    }
    assert!(res.ban_events.iter().all(|b| b.target >= 5));
    assert!(res.final_metric > 0.2, "accuracy after recovery: {}", res.final_metric);
}

#[test]
#[ignore = "expensive: 400-step MLP run with full signatures (minutes); run with --ignored"]
fn mlp_recovers_accuracy_after_attack() {
    // Scaled-down Fig. 3 scenario: 8 peers, 3 Byzantine sign-flippers
    // attacking from step 30, τ=1, 1 validator.
    let ds = Arc::new(SynthVision::new(1, 32, 10));
    let model: Arc<dyn GradientSource> = Arc::new(MlpModel::new(ds, 24, 8));
    let mut c = RunConfig::quick(8, 400);
    c.byzantine = vec![5, 6, 7];
    c.attack = Some((
        AdversarySpec::parse("sign_flip:1000").unwrap(),
        AttackSchedule::from_step(30),
    ));
    c.protocol.tau = TauPolicy::Fixed(1.0);
    c.protocol.delta_max = 3.0;
    c.opt = OptSpec::Sgd {
        schedule: LrSchedule::Constant(0.12),
        momentum: 0.9,
        nesterov: true,
    };
    c.eval_every = 20;
    let res = run_btard(&c, model);
    for byz in [5usize, 6, 7] {
        assert!(
            res.ban_events.iter().any(|b| b.target == byz),
            "byz {byz} unbanned: {:?}",
            res.ban_events
        );
    }
    assert!(res.ban_events.iter().all(|b| b.target >= 5));
    assert!(res.final_metric > 0.5, "accuracy after recovery: {}", res.final_metric);
}

#[test]
fn clipped_sgd_variant_runs_and_converges() {
    let src = quad(64);
    let mut c = cfg(4, 200, &src);
    c.clip_lambda = Some(8.0);
    let res = run_btard(&c, src);
    assert!(res.ban_events.is_empty());
    assert!(res.final_metric < 1.0, "subopt {}", res.final_metric);
}

#[test]
fn communication_is_linear_in_d_plus_n_squared() {
    // Per-peer bytes for (d1, n) vs (d2, n): ratio ≈ d2/d1 once d
    // dominates; and for fixed d, growing n must NOT grow per-peer bytes
    // by O(n) (that's the PS robust-aggregation regime).
    let run = |dim: usize, n: usize| {
        let src = quad(dim);
        let mut c = cfg(n, 6, &src);
        c.protocol.n0 = n;
        c.verify_signatures = false; // isolate traffic accounting
        let res = run_btard(&c, src);
        *res.peer_bytes.iter().max().unwrap() as f64
    };
    let small_d = run(2048, 4);
    let big_d = run(16384, 4);
    let ratio = big_d / small_d;
    assert!(
        ratio > 4.0 && ratio < 10.0,
        "d-scaling ratio {ratio} (want ≈ 8, the gradient term dominates)"
    );
    // n-scaling at fixed d: butterfly keeps per-peer gradient traffic
    // ≈ constant; overhead adds O(n²) scalars ≪ d here.
    let n4 = run(16384, 4);
    let n8 = run(16384, 8);
    assert!(
        n8 / n4 < 2.0,
        "per-peer bytes doubled with n: {n4} -> {n8} (PS-like scaling!)"
    );
}

#[test]
fn tau_infinite_still_bans_but_allows_transient_damage() {
    // The Lemma E.4 regime: no clipping (τ=∞); attackers do transient
    // damage but are still detected and banned via validation.
    let src = quad(64);
    let mut c = cfg(4, 250, &src);
    c.protocol.tau = TauPolicy::Infinite;
    c.byzantine = vec![3];
    c.attack = Some((
        AdversarySpec::parse("sign_flip:10").unwrap(),
        AttackSchedule::from_step(20),
    ));
    let res = run_btard(&c, src);
    assert!(res.ban_events.iter().any(|b| b.target == 3));
    assert!(res.final_metric < 5.0, "no recovery: {}", res.final_metric);
}

#[test]
fn validators_spend_recomputation_budget() {
    let src = quad(64);
    let c = cfg(4, 30, &src);
    let res = run_btard(&c, src);
    // m=1 validator per step recomputes one gradient per step (per peer
    // thread doing validation): ≥ ~steps/2 recomputes across the run.
    assert!(res.recomputes >= 10, "recomputes {}", res.recomputes);
}
