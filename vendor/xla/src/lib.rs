//! Offline stub of the `xla` PJRT bindings used by `btard::runtime`.
//!
//! The testbed image carries no XLA shared library, so this stub keeps
//! the runtime layer source-compatible while reporting "XLA unavailable"
//! from `PjRtClient::cpu()`. The AOT integration tests self-skip when no
//! artifacts directory exists, so the stub is never exercised beyond
//! that first error. Replace this path dependency with the real
//! bindings to run the compiled artifacts.

use std::fmt;

/// Stub error: carries a static explanation.
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla stub: {}", self.0)
    }
}

fn unavailable() -> Error {
    Error("built against the offline xla stub (no PJRT runtime in this image)".to_string())
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(unavailable())
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable())
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(unavailable())
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable())
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable())
    }
}

pub struct Literal;

impl Literal {
    pub fn vec1(_values: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Err(unavailable())
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        Err(unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(unavailable())
    }
}
