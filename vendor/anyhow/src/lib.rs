//! Minimal, std-only shim for the subset of the `anyhow` API this
//! workspace uses: `anyhow!`, `Error`, `Result`, and the `Context`
//! extension trait. The testbed builds offline, so the real crate is
//! unavailable; this shim keeps the call sites source-compatible.
//!
//! Display follows anyhow's convention: `{}` prints the outermost
//! message, `{:#}` prints the whole chain (`outer: inner: root`), and
//! `{:?}` prints the message followed by a `Caused by:` list.

use std::fmt;

/// An error: a root message plus the contexts wrapped around it, most
/// recent (outermost) last.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message (root of the chain).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.push(context.to_string());
        self
    }

    /// The outermost message.
    fn outer(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            let mut first = true;
            for msg in self.chain.iter().rev() {
                if !first {
                    write!(f, ": ")?;
                }
                write!(f, "{msg}")?;
                first = false;
            }
            Ok(())
        } else {
            write!(f, "{}", self.outer())
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.outer())?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for msg in self.chain.iter().rev().skip(1) {
                write!(f, "\n    {msg}")?;
            }
        }
        Ok(())
    }
}

// Like the real anyhow: any std error converts into `Error`. (`Error`
// itself deliberately does not implement `std::error::Error`, which is
// what makes this blanket impl coherent.)
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to the error variant of a `Result` (or to `None`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// `anyhow!("...")` — format a new `Error`.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
}

/// `bail!("...")` — early-return an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fail() -> Result<()> {
        Err(anyhow!("root {}", 42))
    }

    #[test]
    fn chain_formats() {
        let e = fail().context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: root 42");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn io_error_converts() {
        fn read() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(read().is_err());
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("missing {}", "x")).unwrap_err();
        assert_eq!(format!("{e}"), "missing x");
    }
}
